"""Timed execution of registered collective plans (the telemetry PROBE).

Two executors behind one ``measure`` protocol:

* :class:`LiveProbe` — times the real shard_map lowerings of every
  executable plan (allgather / dispatch / combine) on the live mesh with
  ``block_until_ready`` wall clocks.  This is what a deployment points
  the monitor at.
* :class:`SimProbe` — a pure-simulation fallback: "executes" a plan by
  scoring its ledger under a hidden :class:`GroundTruth` (true per-link
  bandwidths + true overhead constants, optionally noisy).  The truth is
  injectable and degradable, which makes the whole
  probe -> store -> fit -> re-plan loop testable on CPU: degrade the
  truth's inter-server links 4x and the fitted model must move.

:func:`probe_sweep` runs every registered plan for an op over a payload
sweep and emits schema-versioned records for the
:class:`~repro.telemetry.store.CalibrationStore` — each record carries
the predicted time under the CURRENT planner calibration next to the
measured time, plus the per-link-class bottleneck bytes the fitter
regresses against.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core import plan as plan_ir
from repro.core.latency_model import DEFAULT, HardwareModel, score_ledger
from repro.core.planner import Planner, bucket_payload
from repro.core.topology import Topology

from .store import SCHEMA_VERSION, topo_key

# default payload sweeps: wide enough to pin both the alpha intercept
# (small payloads) and the 1/bw slope (large payloads)
ALLGATHER_SWEEP = (256 << 10, 1 << 20, 4 << 20, 16 << 20, 64 << 20)
DISPATCH_BATCH_SWEEP = (32, 128, 512, 2048)
DEFAULT_OPS = ("allgather", "dispatch", "combine")


class ProbeTimeout(RuntimeError):
    """A probe attempt exceeded its deadline (live) or targeted a link
    the ground truth has blacked out (sim) — the fabric-side signal the
    failure detector turns into dead-link declarations."""


@dataclasses.dataclass(frozen=True)
class ProbePolicy:
    """Bounded-retry policy for one probe attempt.

    A probe that times out (or crashes) is retried up to ``retries``
    times with exponential backoff — ``backoff_s * backoff_mult**k``,
    jittered by ±``jitter`` fraction so a fleet of probers never
    synchronizes its retry storms.  ``timeout_s`` is the per-attempt
    soft deadline enforced by :class:`LiveProbe` wall clocks (``None``
    disables it; :class:`SimProbe` timeouts are truth-driven instead).
    ``sleep`` is injectable so tests and the sim harness never actually
    wait.
    """

    timeout_s: Optional[float] = None
    retries: int = 2
    backoff_s: float = 0.02
    backoff_mult: float = 2.0
    jitter: float = 0.25
    sleep: object = time.sleep

    def delays(self):
        rng = np.random.default_rng()
        for k in range(max(0, self.retries)):
            d = self.backoff_s * self.backoff_mult ** k
            if self.jitter:
                d *= 1.0 + self.jitter * float(rng.uniform(-1.0, 1.0))
            yield d

    def run(self, fn):
        """``fn()`` with bounded retry; re-raises the final failure."""
        last = None
        for delay in itertools.chain(self.delays(), (None,)):
            try:
                return fn()
            except Exception as e:           # noqa: BLE001 — policy layer
                last = e
                if delay is None:
                    raise
                self.sleep(delay)
        raise last  # pragma: no cover — unreachable


DEFAULT_POLICY = ProbePolicy()


def measure_safely(executor, op: str, plan_name: str, payload_bytes: float,
                   topo: Topology, *, policy: ProbePolicy = DEFAULT_POLICY,
                   **measure_kw) -> Optional[float]:
    """One probe measurement under the retry policy; ``None`` (plus a
    ``repro_probe_failures_total{reason}`` increment) when every attempt
    failed, so a dark rail or a crashing lowering skips ONE record
    instead of killing the whole calibration cycle."""
    try:
        return policy.run(lambda: executor.measure(
            op, plan_name, payload_bytes, topo, **measure_kw))
    except ProbeTimeout:
        reason = "timeout"
    except Exception:                        # noqa: BLE001 — harden the cycle
        reason = "error"
    from . import metrics as _metrics
    _metrics.default_registry()["repro_probe_failures_total"].inc(
        reason=reason, fabric=topo.name)
    return None


def default_payloads(op: str, token_bytes: int = 7168) -> tuple:
    if op == "allgather":
        return ALLGATHER_SWEEP
    return tuple(b * token_bytes for b in DISPATCH_BATCH_SWEEP)


def link_class(topo: Topology, src: int, dst: int) -> str:
    """Fit class of one link: ``intra`` (same server / all of a full
    mesh) or ``inter`` (rail)."""
    return ("intra" if topo.server_of(src) == topo.server_of(dst)
            else "inter")


def link_role(topo: Topology, src: int, dst: int) -> str:
    """Directed fit ROLE of one link: ``intra``, or one role per ordered
    server pair for rails (``inter:0>1`` vs ``inter:1>0``).  Roles are
    the per-link refinement of :func:`link_class`: on an asymmetric
    fabric like ``2x8asym`` the two rail directions carry different
    bandwidths, and a class-level fit would collapse both onto one
    "inter" line — per-role regression keeps each direction's slope."""
    sa, sb = topo.server_of(src), topo.server_of(dst)
    if sa == sb:
        return "intra"
    return f"inter:{sa}>{sb}"


def _ledger_group_bytes(ledger: plan_ir.Ledger, group_fn) -> dict:
    out: dict = {}
    for (a, b), v in ledger.link_bytes.items():
        g = group_fn(ledger.topo, a, b)
        out[g] = max(out.get(g, 0.0), float(v))
    return out


def ledger_class_bytes(ledger: plan_ir.Ledger) -> dict:
    """Max per-link bytes per link class — the regressors the fitter
    uses (the bottleneck-link term of the latency model is a max, so the
    heaviest link of each class is the right x value)."""
    out = {"intra": 0.0, "inter": 0.0}
    out.update(_ledger_group_bytes(ledger, link_class))
    return out


def ledger_role_bytes(ledger: plan_ir.Ledger) -> dict:
    """Max per-link bytes per directed link ROLE (see :func:`link_role`)
    — the per-direction regressors that keep asymmetric fabrics'
    forward/return rails on separate fit lines."""
    return _ledger_group_bytes(ledger, link_role)


# ---------------------------------------------------------------------------
# simulated execution backend (injectable ground truth)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroundTruth:
    """What the fabric ACTUALLY delivers, hidden from the planner.

    ``link_bw`` overrides true per-link bandwidths (sorted tuple, like
    ``HardwareModel.link_bw``); ``noise`` is a lognormal sigma applied to
    every measurement (run-to-run jitter); ``dead_links`` are directed
    links that are ACTUALLY dark — any probe whose ledger charges one
    times out (:class:`ProbeTimeout`) instead of returning a number,
    exactly what a blacked-out rail does to a live prober.  The planner
    never sees this object — only the probe's measured times.
    """

    hw: HardwareModel = DEFAULT
    link_bw: tuple = ()
    noise: float = 0.0
    seed: int = 0
    dead_links: tuple = ()

    def true_hw(self) -> HardwareModel:
        if not self.link_bw:
            return self.hw
        return self.hw.recalibrated({"links": dict(self.link_bw)})

    def with_links(self, links: Mapping) -> "GroundTruth":
        merged = dict(self.link_bw)
        merged.update({tuple(k): float(v) for k, v in dict(links).items()})
        return dataclasses.replace(self,
                                   link_bw=tuple(sorted(merged.items())))

    def degraded(self, topo: Topology, factor: float,
                 which: str = "inter") -> "GroundTruth":
        """Truth with every ``which``-class link of ``topo`` delivering
        ``factor``x less bandwidth than it currently does — the long-term
        stress-test scenario (§6: deployed links drift off datasheet)."""
        cur = dict(self.link_bw)
        links = {}
        for key, ln in topo.links.items():
            if link_class(topo, *key) == which:
                links[key] = cur.get(key, ln.bw) / float(factor)
        return self.with_links(links)

    def with_dead(self, links) -> "GroundTruth":
        """Truth with ``links`` (directed ``(src, dst)`` pairs) fully
        dark — the scripted rail blackout of the failure-events soak."""
        dead = set(self.dead_links)
        dead.update((int(a), int(b)) for a, b in links)
        return dataclasses.replace(self, dead_links=tuple(sorted(dead)))


class SimProbe:
    """Simulation executor: scores the plan's ledger under the ground
    truth (+ lognormal noise).  Same ``measure`` protocol as LiveProbe,
    so the monitor is executor-agnostic."""

    source = "sim"

    def __init__(self, truth: GroundTruth = GroundTruth()) -> None:
        self.truth = truth
        self._rng = np.random.default_rng(truth.seed)

    def measure(self, op: str, plan_name: str, payload_bytes: float,
                topo: Topology, *, ledger: Optional[plan_ir.Ledger] = None,
                knobs: Optional[dict] = None, **scenario_kw) -> float:
        if ledger is None:
            plan = plan_ir.get_plan(op, plan_name)
            scenario = Planner._scenario(op, topo, scenario_kw)
            ledger = plan.simulate(scenario, payload_bytes, **(knobs or {}))
        if self.truth.dead_links:
            dead = set(self.truth.dead_links)
            for key in ledger.link_bytes:
                if key in dead:
                    raise ProbeTimeout(
                        f"{op}/{plan_name} probe crossed dark link "
                        f"{key[0]}->{key[1]}")
        t = score_ledger(ledger, self.truth.true_hw())
        if self.truth.noise:
            t *= float(np.exp(self._rng.normal(0.0, self.truth.noise)))
        return float(t)


# ---------------------------------------------------------------------------
# live execution backend (times the real lowerings on the mesh)
# ---------------------------------------------------------------------------

class LiveProbe:
    """Times the executable lowerings of registered plans on a live mesh.

    ``axis_name`` carries the AllGather; ``ep_axis`` (and the optional
    ``pod_axis``) carry the MoE dispatch/combine.  Wall-clock = min over
    ``repeats`` of a blocked jitted call, after ``warmup`` compile+run.
    On CPU hosts the numbers time the collective *emulation*, not a
    fabric — deployments run this on the real mesh; tests and CI use
    :class:`SimProbe`.
    """

    source = "live"

    def __init__(self, mesh, *, axis_name: str = "model",
                 ep_axis: str = "data", pod_axis: Optional[str] = None,
                 repeats: int = 3, warmup: int = 1,
                 timeout_s: Optional[float] = None) -> None:
        self.mesh = mesh
        self.axis_name = axis_name
        self.ep_axis = ep_axis
        self.pod_axis = pod_axis
        self.repeats = int(repeats)
        self.warmup = int(warmup)
        self.timeout_s = timeout_s

    def _time(self, fn, *args) -> float:
        """min-of-repeats blocked wall clock, under the soft per-probe
        deadline: a blocked call cannot be interrupted mid-flight, so a
        hung collective is detected as soon as it RETURNS past the
        deadline (or as soon as the warmup run blows it) and surfaces as
        :class:`ProbeTimeout` for the retry policy / failure detector
        instead of silently poisoning the calibration store."""
        import jax

        def timed(run_fn) -> float:
            t0 = time.monotonic()
            jax.block_until_ready(run_fn())
            dt = time.monotonic() - t0
            if self.timeout_s is not None and dt > self.timeout_s:
                raise ProbeTimeout(
                    f"probe took {dt:.3f}s > deadline {self.timeout_s:.3f}s")
            return dt

        for _ in range(max(1, self.warmup)):
            timed(lambda: fn(*args))
        best = float("inf")
        for _ in range(max(1, self.repeats)):
            best = min(best, timed(lambda: fn(*args)))
        return best

    def measure(self, op: str, plan_name: str, payload_bytes: float,
                topo: Topology, *, ledger=None,
                knobs: Optional[dict] = None, **scenario_kw) -> float:
        if op == "allgather":
            return self._measure_allgather(plan_name, payload_bytes,
                                           knobs or {})
        if op == "linkprobe":
            return self._measure_linkprobe(payload_bytes, scenario_kw)
        return self._measure_moe(op, plan_name, payload_bytes, scenario_kw)

    def _measure_linkprobe(self, payload_bytes: float,
                           scenario_kw: dict) -> float:
        """Directed point-to-point transfer: every rank of the source
        server block ppermutes its buffer to the same-index rank of the
        destination block — one direction's rails carry traffic, nothing
        else does.  Server blocks come from the mesh: the pod axis when
        present, else the ep axis split into two halves."""
        import functools

        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P

        from repro.parallel.compat import shard_map

        src = int(scenario_kw.get("src_server", 0))
        dst = int(scenario_kw.get("dst_server", 1))
        if self.pod_axis:
            axis, n_servers = self.pod_axis, self.mesh.shape[self.pod_axis]
            per = 1
        else:
            axis, n_servers = self.ep_axis, 2
            per = self.mesh.shape[self.ep_axis] // 2
        src %= n_servers
        dst %= n_servers
        if per < 1 or src == dst and n_servers > 1:
            dst = (src + 1) % n_servers
        perm = [(src * per + i, dst * per + i) for i in range(max(1, per))]
        if "src_node" in scenario_kw and "dst_node" in scenario_kw:
            # single-rail probe (the failure detector's granularity):
            # exactly one ordered rank pair carries traffic
            total = n_servers * max(1, per)
            perm = [(int(scenario_kw["src_node"]) % total,
                     int(scenario_kw["dst_node"]) % total)]
        feat = 64
        rows = max(1, int(payload_bytes) // (4 * feat))
        n = int(np.prod([self.mesh.shape[a] for a in (axis,)]))
        x = jnp.zeros((n * rows, feat), jnp.float32)
        body = functools.partial(lax.ppermute, axis_name=axis, perm=perm)
        fn = jax.jit(shard_map(body, mesh=self.mesh, in_specs=P(axis),
                               out_specs=P(axis), check_vma=False))
        with self.mesh:
            return self._time(fn, x)

    def _measure_allgather(self, plan_name: str, payload_bytes: float,
                           knobs: dict) -> float:
        import functools

        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.core import collectives as cl
        from repro.parallel.compat import shard_map

        plan = plan_ir.get_plan("allgather", plan_name)
        if not plan.executable:
            raise ValueError(f"plan {plan_name!r} has no lowering to time")
        kw = plan.shard_map_kwargs(**{**plan.default_knobs(), **knobs})
        n = int(np.prod([self.mesh.shape[a]
                         for a in (self.axis_name,)]))
        feat = 64
        rows = max(1, int(payload_bytes) // (4 * feat))
        x = jnp.zeros((n * rows, feat), jnp.float32)
        if kw.get("mode") is None:
            body = functools.partial(cl.allgather_reference,
                                     axis_name=self.axis_name)
        else:
            body = functools.partial(cl.multiwrite_allgather,
                                     axis_name=self.axis_name,
                                     mode=kw["mode"], split=kw["split"])
        fn = jax.jit(shard_map(body, mesh=self.mesh,
                               in_specs=P(self.axis_name),
                               out_specs=P(self.axis_name),
                               check_vma=False))
        with self.mesh:
            return self._time(fn, x)

    def _measure_moe(self, op: str, plan_name: str, payload_bytes: float,
                     scenario_kw: dict) -> float:
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.core import collectives as cl
        from repro.parallel.compat import shard_map

        plan = plan_ir.get_plan(op, plan_name)
        kw = plan.shard_map_kwargs()
        scheme = kw.get("moe_scheme") or kw.get("moe_combine") or "baseline"
        p = self.mesh.shape[self.pod_axis] if self.pod_axis else 1
        d = self.mesh.shape[self.ep_axis]
        ranks = p * d
        top_k = int(scenario_kw.get("top_k", 8))
        per_rank = max(1, int(scenario_kw.get("num_experts", 64)) // ranks)
        num_experts = per_rank * ranks
        top_k = min(top_k, num_experts)
        token_bytes = int(scenario_kw.get("token_bytes", 7168))
        h = max(8, min(1024, token_bytes // 4))
        n_per_rank = max(1, int(payload_bytes) // token_bytes)
        epmesh = cl.EPMesh(pod_axis=self.pod_axis if p > 1 else None,
                           ep_axis=self.ep_axis, num_pods=p, ep_per_pod=d)
        dcfg = cl.DispatchConfig(num_experts=num_experts, top_k=top_k,
                                 pod_capacity=min(1.0, 2.0 * top_k / p),
                                 ep_capacity=min(1.0, 2.0 * (top_k / p) / d),
                                 expert_capacity=1.0)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.normal(
            size=(n_per_rank * ranks, h)).astype(np.float32))
        logits = jnp.asarray(rng.normal(
            size=(n_per_rank * ranks, num_experts)).astype(np.float32))
        time_combine = op == "combine"

        def body(tok, lg):
            gates, ids = cl.route_topk(lg, top_k)
            if scheme == "hierarchical":
                exp_tok, exp_gate, st = cl.hierarchical_dispatch(
                    tok, ids, gates, dcfg, epmesh)
                if time_combine:
                    return cl.hierarchical_combine(exp_tok, exp_gate, st)
            else:
                exp_tok, exp_gate, st = cl.baseline_dispatch(
                    tok, ids, gates, dcfg, epmesh)
                if time_combine:
                    return cl.baseline_combine(exp_tok, exp_gate, st)
            return jnp.sum(exp_tok, axis=(1, 2))   # force materialization

        axes = ((self.pod_axis, self.ep_axis) if epmesh.pod_axis
                else (self.ep_axis,))
        fn = jax.jit(shard_map(body, mesh=self.mesh,
                               in_specs=(P(axes), P(axes)),
                               out_specs=P(axes), check_vma=False))
        with self.mesh:
            return self._time(fn, tokens, logits)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def attributed_bottleneck(ledger: plan_ir.Ledger,
                          hw: Optional[HardwareModel]) -> tuple[int, int]:
    """Bottleneck link of a ledger under the MEASURED per-link
    bandwidths (``hw.link_bw``), falling back to the topology's nominal
    ones where no measurement exists.

    This is the per-role fit-attribution fix (ROADMAP): under a
    single-direction degradation the nominal-bandwidth argmax ties
    between the two rail directions and can attribute a slow-direction
    record to the healthy reverse role, dragging BOTH role fits down and
    re-tripping drift every cycle.  Attributing under the fitted model
    (available from the first recalibration on) pins the record to the
    direction that actually bottlenecked it, so the churn stops after
    one recalibration.  Ties break toward the smaller link key for
    determinism."""
    measured = dict(hw.link_bw) if hw is not None and hw.link_bw else {}
    best_key, best_t = None, -1.0
    for key, nbytes in sorted(ledger.link_bytes.items()):
        bw = measured.get(key, ledger.topo.link(*key).bw)
        t = nbytes / bw
        if t > best_t:
            best_key, best_t = key, t
    return best_key


def probe_record(op: str, plan: plan_ir.CollectivePlan, payload_bytes: float,
                 topo: Topology, measured_s: float, predicted_s: float,
                 ledger: plan_ir.Ledger, source: str,
                 knobs: Optional[dict] = None,
                 hw: Optional[HardwareModel] = None) -> dict:
    """One schema-versioned store record for a timed plan execution.
    Pass the planner's current ``hw`` so the bottleneck class/role is
    attributed under measured link bandwidths (see
    :func:`attributed_bottleneck`); without it attribution falls back to
    the topology's nominal bandwidths."""
    cls_bytes = ledger_class_bytes(ledger)
    bsrc, bdst = attributed_bottleneck(ledger, hw)
    return {
        "schema": SCHEMA_VERSION,
        "ts": time.time(),
        "fabric": topo_key(topo),
        "fabric_name": topo.name,
        "op": op,
        "plan": plan.name,
        "knobs": dict(knobs or plan.default_knobs()),
        "payload_bytes": float(payload_bytes),
        "bucket": bucket_payload(payload_bytes),
        "predicted_s": float(predicted_s),
        "measured_s": float(measured_s),
        "bottleneck_link": [int(bsrc), int(bdst)],
        "bottleneck_class": link_class(topo, bsrc, bdst),
        "bottleneck_role": link_role(topo, bsrc, bdst),
        "class_bytes": cls_bytes,
        "role_bytes": ledger_role_bytes(ledger),
        "stages": int(ledger.stages),
        "relayed": bool(ledger.relayed),
        "source": source,
    }


def probe_sweep(topo: Topology, executor, *,
                ops: Sequence[str] = DEFAULT_OPS,
                plans: Optional[Sequence[str]] = None,
                payloads: Optional[Mapping[str, Sequence[float]]] = None,
                hw: HardwareModel = DEFAULT,
                token_bytes: int = 7168,
                policy: ProbePolicy = DEFAULT_POLICY,
                **scenario_kw) -> list[dict]:
    """Time every registered plan of every op over a payload sweep.

    ``hw`` is the calibration the PREDICTED times are scored under (pass
    the planner's current model so record drift reflects model error);
    the executor supplies the measured side.  Probes run under
    ``policy`` (bounded retry + backoff): a probe that still fails is
    counted and SKIPPED — no record — so a dark rail never crashes the
    cycle or poisons the store.  Returns store-ready records.
    """
    records: list[dict] = []
    kw = dict(scenario_kw)
    kw.setdefault("token_bytes", token_bytes)
    for op in ops:
        sweep = (payloads or {}).get(op) if payloads else None
        if sweep is None:
            sweep = default_payloads(op, token_bytes)
        live = getattr(executor, "source", "") == "live"
        for plan in plan_ir.plans_for(op, executable_only=live):
            if plans is not None and plan.name not in plans:
                continue
            scenario = Planner._scenario(op, topo, kw)
            knobs = plan.default_knobs()
            for payload in sweep:
                ledger = plan.simulate(scenario, payload, **knobs)
                predicted = score_ledger(ledger, hw)
                measured = measure_safely(
                    executor, op, plan.name, payload, topo, policy=policy,
                    ledger=ledger, knobs=knobs, **kw)
                if measured is None:
                    continue
                records.append(probe_record(
                    op, plan, payload, topo, measured, predicted, ledger,
                    getattr(executor, "source", "unknown"), knobs, hw=hw))
    return records


# payload sweep of the directed rail microbenchmark: enough distinct
# points to clear the fitter's confidence floor per direction
DIRECTION_SWEEP = (256 << 10, 1 << 20, 4 << 20, 16 << 20)


def probe_link_directions(topo: Topology, executor, *,
                          payloads: Sequence[float] = DIRECTION_SWEEP,
                          hw: HardwareModel = DEFAULT,
                          policy: ProbePolicy = DEFAULT_POLICY) -> list[dict]:
    """Directed point-to-point microbenchmark of every ordered server
    pair that has rails (the "linkprobe"/"p2p" plan).

    The collective probe sweeps only ever regress a direction that
    BOTTLENECKS some plan — on an asymmetric fabric the fast forward
    rails never do, so they stayed nominal forever (ROADMAP debt).
    These records bottleneck on exactly one direction by construction,
    so ``fit_link_roles`` gets a payload sweep for every direction and
    the fitted model covers both sides of an asymmetric fabric."""
    plan = plan_ir.get_plan("linkprobe", "p2p")
    pairs = sorted({(topo.server_of(a), topo.server_of(b))
                    for (a, b) in topo.links
                    if topo.server_of(a) != topo.server_of(b)})
    records: list[dict] = []
    for sa, sb in pairs:
        scenario = plan_ir.LinkProbeScenario(topo, sa, sb)
        for payload in payloads:
            ledger = plan.simulate(scenario, payload)
            predicted = score_ledger(ledger, hw)
            measured = measure_safely(
                executor, "linkprobe", "p2p", payload, topo, policy=policy,
                ledger=ledger, knobs={}, src_server=sa, dst_server=sb)
            if measured is None:
                continue
            records.append(probe_record(
                "linkprobe", plan, payload, topo, measured, predicted,
                ledger, getattr(executor, "source", "unknown"), {}, hw=hw))
    return records
