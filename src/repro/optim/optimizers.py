"""Optimizers in pure JAX (no optax): AdamW, Adafactor, Lion, SGD.

An :class:`Optimizer` is an (init, update) pair over param pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

ZeRO-1 note: optimizer state inherits the parameter PartitionSpecs under
pjit (states are elementwise over params), so FSDP-sharded params give
sharded m/v for free; ``state_specs`` mirrors a param-spec pytree onto the
state for explicit in_shardings.

``opt_dtype`` controls moment storage (fp32 default; bf16 halves optimizer
HBM for the 1T-param kimi-k2 cell — the error is absorbed by Adam's
normalization, a standard large-model trick).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params, step)
    state_specs: Callable[[Any], Any]        # param_specs -> state specs


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def tree_zeros_like(params, dtype=None):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          opt_dtype=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": tree_zeros_like(params, opt_dtype),
                "v": tree_zeros_like(params, opt_dtype)}

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step
        bc2 = 1 - b2 ** step

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * gf
            v_new = b2 * v32 + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — frontier-scale memory savings)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return jax.tree_util.tree_map(per_leaf, params)

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)
        rho = 1.0 - step ** (-decay)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in s:
                vr = rho * s["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * s["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = gf / jnp.sqrt(vhat + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = rho * s["v"] + (1 - rho) * g2
                u = gf / jnp.sqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return u, new_s

        flat, treedef = jax.tree_util.tree_flatten(
            grads, is_leaf=lambda x: isinstance(x, jnp.ndarray))
        sflat = treedef.flatten_up_to(state)
        pflat = treedef.flatten_up_to(params)
        pairs = [upd(g, s, p) for g, s, p in zip(flat, sflat, pflat)]
        updates = treedef.unflatten([u for u, _ in pairs])
        new_state = treedef.unflatten([s for _, s in pairs])
        return updates, new_state

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def per_leaf(spec):
            # factored state drops the last / second-to-last dims; emitting
            # exact specs requires shapes, so replicate factored moments
            # (they are tiny) — P() is safe and cheap.
            return {"vr": P(), "vc": P()}
        return jax.tree_util.tree_map(
            per_leaf, param_specs,
            is_leaf=lambda x: not isinstance(x, dict))

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Lion
# ---------------------------------------------------------------------------

def lion(lr=1e-4, b1=0.9, b2=0.99, weight_decay=0.1,
         opt_dtype=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": tree_zeros_like(params, opt_dtype)}

    def update(grads, state, params, step):
        lr_t = lr_fn(jnp.asarray(step, jnp.float32))

        def upd(g, m, p):
            gf = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32)
            u = -lr_t * (jnp.sign(b1 * m32 + (1 - b1) * gf)
                         + weight_decay * p.astype(jnp.float32))
            m_new = b2 * m32 + (1 - b2) * gf
            return u, m_new.astype(m.dtype)

        out = jax.tree_util.tree_map(upd, grads, state["m"], params)
        updates = jax.tree_util.tree_map(
            lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(
            lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m}

    def state_specs(param_specs):
        return {"m": param_specs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# SGD (baseline / tests)
# ---------------------------------------------------------------------------

def sgd(lr=1e-2, momentum=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum:
            return {"m": tree_zeros_like(params, jnp.float32)}
        return {}

    def update(grads, state, params, step):
        lr_t = lr_fn(jnp.asarray(step, jnp.float32))
        if momentum:
            m = jax.tree_util.tree_map(
                lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                state["m"], grads)
            updates = jax.tree_util.tree_map(lambda m_: -lr_t * m_, m)
            return updates, {"m": m}
        updates = jax.tree_util.tree_map(
            lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, state

    def state_specs(param_specs):
        return {"m": param_specs} if momentum else {}

    return Optimizer(init, update, state_specs)


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""
    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params, step)
    return Optimizer(opt.init, update, opt.state_specs)


REGISTRY = {"adamw": adamw, "adafactor": adafactor, "lion": lion,
            "sgd": sgd}
