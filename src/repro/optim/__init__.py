from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, lion, sgd, clip_by_global_norm,
    cosine_schedule, chain_clip,
)
