"""Planner-informed admission control.

The controller answers one question per scheduling iteration: *how many
queued requests may join the decode batch right now?*  Its policy is
informed by the planner's own batch-dependent knowledge (the Fig 8
scheme crossovers reproduced since PR 1):

- :class:`PlannerProbe` is the latency oracle — planner decisions for
  the decode-phase MoE round trip (dispatch + combine) at any batch
  bucket, the emergent scheme-crossover batch
  (:func:`~repro.core.planner.emergent_flip_batch`), and the penalty of
  executing a *stale* scheme (the one bound for a smaller bucket) at a
  grown payload.  Every query rides the planner's LRU, so per-step
  admission checks never re-sweep.

- :class:`AdmissionController.decide` grows the batch greedily up to
  capacity, EXCEPT when the planner predicts the grown bucket's decode
  step would blow the TPOT SLO (the ``phase_budgets`` decode budget by
  default) — then it holds the batch at the largest SLO-feasible size
  below the crossover.  When growth crosses a batch-bucket boundary and
  IS admitted, the decision carries ``stage_bucket`` so the scheduler
  stages the next bucket's plan through ``PlanBinder`` ahead of the
  join: the swap at the next step boundary is a pointer flip, never a
  cold retrace.

A ``policy="greedy"`` controller is the crossover-oblivious baseline
``bench_serving`` compares against: it admits everything and never
stages a re-bind, so a grown batch keeps executing the scheme that won
at the small bucket.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.plan import batch_bucket

POLICIES = ("planner", "greedy")


def _metrics():
    from repro.telemetry import metrics as _m
    return _m.default_registry()


class PlannerProbe:
    """Planner-backed latency oracle for one serving fabric.

    ``token_bytes`` is the per-token activation payload (d_model *
    itemsize, matching the traced dtype).  All queries are scored at
    power-of-two batch buckets and memoized locally on top of the
    planner's own LRU.
    """

    def __init__(self, topo, *, token_bytes: int = 14336,
                 num_experts: int = 64, top_k: int = 8, hw=None,
                 planner=None, d_model: int = 7168, f_shard: int = 2048,
                 tp: int = 1) -> None:
        from repro.core.planner import default_planner
        self.topo = topo
        self.token_bytes = int(token_bytes)
        self.num_experts = int(num_experts)
        self.top_k = int(top_k)
        self.hw = hw
        self.planner = planner or default_planner()
        self.d_model = int(d_model)
        self.f_shard = int(f_shard)
        self.tp = max(1, int(tp))
        self._decisions: dict = {}
        self._xover: Optional[float] = None

    # -- planner decisions ---------------------------------------------------
    def decision(self, op: str, batch: int):
        """Planner decision for ``op`` at the bucketed per-rank batch."""
        b = batch_bucket(max(1, int(batch)))
        key = (op, b)
        d = self._decisions.get(key)
        if d is None:
            from repro.core.latency_model import expert_compute_time_s
            compute_s = expert_compute_time_s(
                b, self.top_k, self.d_model, self.f_shard)
            d = self.planner.choose(
                op, float(b) * self.token_bytes, self.topo, self.hw,
                token_bytes=self.token_bytes, num_experts=self.num_experts,
                top_k=self.top_k, compute_s=compute_s)
            self._decisions[key] = d
        return d

    @staticmethod
    def _candidate_s(decision, scheme: str) -> float:
        """Predicted latency of a SPECIFIC scheme at the decision's
        payload (the stale-plan penalty lookup); falls back to the
        worst scored candidate when the scheme was not swept."""
        for name, _knobs, score in decision.candidates:
            if name == scheme:
                return float(score)
        scores = [float(s) for _n, _k, s in decision.candidates]
        return max(scores) if scores else float(decision.predicted_s)

    def scheme_at(self, batch: int) -> str:
        """Winning decode dispatch scheme at this batch bucket."""
        return self.decision("dispatch", batch).plan

    def decode_step_s(self, batch: int,
                      bound_batch: Optional[int] = None) -> float:
        """Predicted decode-step collective time (dispatch + combine) at
        the bucketed ``batch``.  With ``bound_batch`` given, the step is
        costed as if executing the scheme pair *bound for that bucket* —
        what a crossover-oblivious scheduler actually runs after the
        batch grew past the plan it bound."""
        d = self.decision("dispatch", batch)
        c = self.decision("combine", batch)
        if bound_batch is None or \
                batch_bucket(max(1, bound_batch)) == batch_bucket(
                    max(1, batch)):
            return float(d.predicted_s) + float(c.predicted_s)
        bd = self.decision("dispatch", bound_batch)
        bc = self.decision("combine", bound_batch)
        return (self._candidate_s(d, bd.plan) +
                self._candidate_s(c, bc.plan))

    def prefill_s(self, batch: int, prompt_len: int) -> float:
        """Predicted prefill collective time: the MoE round trip at
        ``batch * prompt_len`` tokens per rank."""
        tokens = max(1, int(batch) * int(prompt_len))
        d = self.decision("dispatch", tokens)
        c = self.decision("combine", tokens)
        return float(d.predicted_s) + float(c.predicted_s)

    def crossover_batch(self) -> float:
        """Smallest per-rank decode batch where the planner leaves the
        baseline dispatch scheme (inf: baseline always wins)."""
        if self._xover is None:
            from repro.core.planner import emergent_flip_batch
            self._xover = emergent_flip_batch(
                "dispatch", self.topo, token_bytes=self.token_bytes,
                hw=self.hw, planner=self.planner,
                num_experts=self.num_experts, top_k=self.top_k)
        return self._xover


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admit: int                      # requests to admit this iteration
    held: int                       # ready requests deferred by policy
    target_batch: int               # in-flight sequences after admission
    stage_bucket: Optional[int]     # bucket plan to stage pre-join, or None
    reason: str


class AdmissionController:
    """Decide per-iteration admission; see module docstring."""

    def __init__(self, probe: Optional[PlannerProbe] = None, *,
                 capacity: int = 64, policy: str = "planner",
                 tpot_slo_s: Optional[float] = None,
                 ttft_slo_s: Optional[float] = None,
                 max_join: Optional[int] = None) -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {POLICIES}")
        self.probe = probe
        self.capacity = max(1, int(capacity))
        self.policy = policy
        self.tpot_slo_s = tpot_slo_s
        self.ttft_slo_s = ttft_slo_s
        # cap on joins per iteration: bounds the prefill chunk a deep
        # backlog can inject between two decode rounds (None: no cap)
        self.max_join = max_join
        self.holds = 0              # iterations that held below crossover
        self.held_requests = 0
        self.rejected = {}          # reason -> count

    def _reject(self, reason: str, n: int) -> None:
        if n <= 0:
            return
        self.rejected[reason] = self.rejected.get(reason, 0) + n
        _metrics()["repro_admission_rejects_total"].inc(n, reason=reason)

    def _max_slo_batch(self, lo: int, hi: int) -> int:
        """Largest target batch in (lo, hi] whose bucketed decode step
        meets the TPOT SLO; ``lo`` when none does."""
        best = lo
        for t in range(hi, lo, -1):
            if self.probe.decode_step_s(t) <= self.tpot_slo_s:
                best = t
                break
        return best

    def decide(self, *, in_flight: int, ready: int,
               oldest_wait_s: float = 0.0,
               bound_bucket: Optional[int] = None) -> AdmissionDecision:
        """One admission verdict.  ``bound_bucket`` is the batch bucket
        of the currently bound/staged plan (None: untracked)."""
        in_flight = max(0, int(in_flight))
        ready = max(0, int(ready))
        if ready == 0:
            return AdmissionDecision(0, 0, in_flight, None, "idle")
        free = self.capacity - in_flight
        if free <= 0:
            self._reject("capacity", ready)
            return AdmissionDecision(0, ready, in_flight, None, "capacity")
        want = min(free, ready)
        if self.max_join is not None:
            want = min(want, max(1, int(self.max_join)))
        target = in_flight + want
        if self.policy == "greedy" or self.probe is None or \
                self.tpot_slo_s is None:
            # crossover-oblivious: admit everything, stage nothing
            return AdmissionDecision(want, 0, target, None, "greedy")
        admit, reason = want, "admit"
        if self.probe.decode_step_s(target) > self.tpot_slo_s:
            feasible = self._max_slo_batch(in_flight, target)
            ttft_pressure = (self.ttft_slo_s is not None and
                             oldest_wait_s > 0.5 * self.ttft_slo_s)
            if ttft_pressure:
                # the queue head is about to blow its TTFT SLO — admit
                # anyway and eat the TPOT band; starving the queue to
                # protect TPOT just moves the SLO violation upstream
                reason = "ttft_pressure"
            else:
                admit = max(0, feasible - in_flight)
                reason = "tpot_slo_hold"
                self.holds += 1
                self.held_requests += want - admit
                self._reject("tpot_slo", want - admit)
        new_target = in_flight + admit
        stage = None
        if admit > 0:
            new_bucket = batch_bucket(max(1, new_target))
            if bound_bucket is not None and \
                    new_bucket != batch_bucket(max(1, bound_bucket)):
                stage = new_bucket
                xover = self.probe.crossover_batch()
                if reason == "admit":
                    reason = ("crossover_rebind"
                              if (xover is not math.inf and
                                  batch_bucket(max(1, bound_bucket)) <
                                  xover <= new_bucket)
                              else "bucket_rebind")
        return AdmissionDecision(admit, want - admit, new_target, stage,
                                 reason)
