"""Continuous-batching serving tier (ISSUE 10).

The traffic side of the millions-of-users path: an open-loop request
queue, an iteration-level :class:`BatchScheduler` (finished sequences
exit and new requests join between decode steps — no drain-the-batch
barrier), and a *planner-informed* :class:`AdmissionController` that
consults the bound ExecutionPlan's batch-dependent scheme crossovers
and phase budgets before growing the decode batch, staging the next
batch bucket's plan through ``PlanBinder`` ahead of admission so batch
growth is a pointer flip (mirroring the PR 9 failover swap).

Dataflow: queue -> admit -> schedule -> bind (see ARCHITECTURE.md).
Everything here is numpy-only and virtual-time (no wall clock), so the
whole tier is simulation-testable on CPU like SimProbe; plugging in a
``ServeEngine`` makes the same scheduler drive real prefill/decode.
"""

from repro.serving.admission import (AdmissionController, AdmissionDecision,
                                     PlannerProbe)
from repro.serving.queue import (DEADLINE_CLASSES, Request, RequestQueue)
from repro.serving.scheduler import BatchScheduler
from repro.serving.traffic import TrafficConfig, TrafficGenerator

__all__ = [
    "AdmissionController", "AdmissionDecision", "BatchScheduler",
    "DEADLINE_CLASSES", "PlannerProbe", "Request", "RequestQueue",
    "TrafficConfig", "TrafficGenerator",
]
