"""Request lifecycle + the deadline-class-aware admission queue.

A :class:`Request` carries its whole serving lifecycle in virtual time
(seconds on the scheduler's clock, never the wall): arrival, admission
(queue exit), first token (TTFT) and finish — the quantities the
per-request SLO classes and the serving histograms cut.

:class:`RequestQueue` is an arrival-time-gated priority FIFO: only
requests whose ``arrival_s`` has passed are visible, and within the
visible set the deadline classes pop in priority order
(``interactive`` before ``standard`` before ``batch``), FIFO inside a
class.  The queue never drops — backpressure is the admission
controller's job, and the stress soak asserts a dark rail drains the
queue without losing a request.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

DEADLINE_CLASSES = ("interactive", "standard", "batch")

# TTFT slack multiplier per deadline class: an interactive request cuts
# its SLO against the raw planner prediction; batch traffic tolerates a
# deep queue before its class degrades.
CLASS_TTFT_SLACK = {"interactive": 1.0, "standard": 2.0, "batch": 8.0}


@dataclasses.dataclass
class Request:
    """One serving request, in virtual time."""

    rid: int
    arrival_s: float = 0.0
    prompt: object = None            # np.ndarray [prompt_len] int32, or None
    prompt_len: int = 0
    max_new: int = 32
    slo_class: str = "standard"
    # -- lifecycle (stamped by the scheduler) --------------------------------
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    tokens: list = dataclasses.field(default_factory=list)
    emitted: int = 0                 # tokens emitted so far (sim + engine)
    eos: bool = False                # finished by EOS (vs max_new)
    # planner predictions captured at admission (SLO denominators)
    predicted_ttft_s: Optional[float] = None
    predicted_tpot_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.slo_class not in DEADLINE_CLASSES:
            raise ValueError(f"unknown deadline class {self.slo_class!r}; "
                             f"expected one of {DEADLINE_CLASSES}")
        if self.prompt is not None and not self.prompt_len:
            self.prompt_len = len(self.prompt)
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    # -- derived latencies ---------------------------------------------------
    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admit_s is None:
            return None
        return self.admit_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token, queue wait included."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token over the decode tail (excludes the
        prefill-produced first token); None until >= 2 tokens landed."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        if self.emitted < 2:
            return None
        return (self.finish_s - self.first_token_s) / (self.emitted - 1)

    @property
    def done(self) -> bool:
        return self.eos or self.emitted >= self.max_new


class RequestQueue:
    """Arrival-gated, deadline-class-prioritized FIFO."""

    def __init__(self) -> None:
        self._pending: List[Request] = []
        self._seq = itertools.count()   # stable FIFO tiebreak
        self._order: dict = {}
        self.pushed = 0
        self.popped = 0

    def push(self, req: Request) -> None:
        self._order[id(req)] = next(self._seq)
        self._pending.append(req)
        self.pushed += 1

    def __len__(self) -> int:
        return len(self._pending)

    def ready(self, now: float) -> List[Request]:
        """Arrived-but-unadmitted requests, in pop order."""
        cls_rank = {c: i for i, c in enumerate(DEADLINE_CLASSES)}
        ready = [r for r in self._pending if r.arrival_s <= now]
        ready.sort(key=lambda r: (cls_rank[r.slo_class], r.arrival_s,
                                  self._order[id(r)]))
        return ready

    def ready_count(self, now: float) -> int:
        return sum(1 for r in self._pending if r.arrival_s <= now)

    def oldest_wait_s(self, now: float) -> float:
        waits = [now - r.arrival_s for r in self._pending
                 if r.arrival_s <= now]
        return max(waits) if waits else 0.0

    def next_arrival_s(self, now: Optional[float] = None) -> Optional[float]:
        """Earliest future arrival (or earliest at all when ``now`` is
        None); None when the queue is empty."""
        times = [r.arrival_s for r in self._pending
                 if now is None or r.arrival_s > now]
        if not times and now is not None:
            times = [r.arrival_s for r in self._pending]
        return min(times) if times else None

    def pop_ready(self, now: float, n: int) -> List[Request]:
        """Admit up to ``n`` arrived requests in priority order."""
        take = self.ready(now)[:max(0, int(n))]
        taken = {id(r) for r in take}
        self._pending = [r for r in self._pending if id(r) not in taken]
        for r in take:
            self._order.pop(id(r), None)
        self.popped += len(take)
        return take
