"""Open-loop traffic generation: seeded Poisson arrivals.

The generator emits a fixed request list up front — interarrival gaps
drawn from an exponential distribution (the open-loop Poisson process
serving benchmarks standard on), prompt/output lengths and deadline
classes drawn from configurable discrete distributions.  Everything is
a pure function of the seed: no wall clock, no global RNG state, so a
scheduler driven by this traffic is deterministic and CPU-testable the
same way SimProbe makes the telemetry loop testable.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.queue import DEADLINE_CLASSES, Request


def _normalize(probs: Optional[Sequence[float]], n: int) -> np.ndarray:
    if probs is None:
        return np.full(n, 1.0 / n)
    p = np.asarray(probs, float)
    if len(p) != n or (p < 0).any() or p.sum() <= 0:
        raise ValueError("probs must be non-negative, same length as "
                         "choices, and sum > 0")
    return p / p.sum()


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Open-loop arrival process, all in virtual seconds."""

    arrival_rate_rps: float = 8.0        # mean requests/second (Poisson)
    num_requests: int = 64
    prompt_lens: Sequence[int] = (128,)
    prompt_len_probs: Optional[Sequence[float]] = None
    max_news: Sequence[int] = (32,)
    max_new_probs: Optional[Sequence[float]] = None
    slo_classes: Sequence[str] = ("standard",)
    slo_class_probs: Optional[Sequence[float]] = None
    vocab: int = 0                        # > 0: draw prompt token ids too
    seed: int = 0

    def __post_init__(self) -> None:
        if self.arrival_rate_rps <= 0:
            raise ValueError("arrival_rate_rps must be > 0")
        if self.num_requests < 1:
            raise ValueError("num_requests must be >= 1")
        for c in self.slo_classes:
            if c not in DEADLINE_CLASSES:
                raise ValueError(f"unknown deadline class {c!r}")


class TrafficGenerator:
    """Deterministic request stream for one :class:`TrafficConfig`."""

    def __init__(self, cfg: TrafficConfig) -> None:
        self.cfg = cfg

    def requests(self) -> List[Request]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        gaps = rng.exponential(1.0 / cfg.arrival_rate_rps,
                               size=cfg.num_requests)
        arrivals = np.cumsum(gaps)
        p_len = _normalize(cfg.prompt_len_probs, len(cfg.prompt_lens))
        p_new = _normalize(cfg.max_new_probs, len(cfg.max_news))
        p_cls = _normalize(cfg.slo_class_probs, len(cfg.slo_classes))
        lens = rng.choice(np.asarray(cfg.prompt_lens, int),
                          size=cfg.num_requests, p=p_len)
        news = rng.choice(np.asarray(cfg.max_news, int),
                          size=cfg.num_requests, p=p_new)
        classes = rng.choice(np.asarray(cfg.slo_classes, object),
                             size=cfg.num_requests, p=p_cls)
        out: List[Request] = []
        for i in range(cfg.num_requests):
            prompt = None
            if cfg.vocab > 0:
                prompt = rng.integers(1, cfg.vocab, size=int(lens[i]),
                                      dtype=np.int64).astype(np.int32)
            out.append(Request(rid=i, arrival_s=float(arrivals[i]),
                               prompt=prompt, prompt_len=int(lens[i]),
                               max_new=int(news[i]),
                               slo_class=str(classes[i])))
        return out
