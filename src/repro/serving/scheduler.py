"""Iteration-level (continuous-batching) request scheduler.

One scheduling **iteration** = (1) land any staged plan swap at the
step boundary (``PlanBinder.swap_if_pending`` — a pointer flip when the
bucket plan was prefetched), (2) consult the admission controller and
prefill the joining requests as a new *cohort*, (3) run one decode
round over every in-flight cohort.  Finished sequences release their
admission capacity at the iteration boundary and new requests join
right behind them — there is no drain-the-batch barrier
(``static_batching=True`` restores the barrier as the benchmark
baseline: nothing is admitted while any cohort is in flight).

A **cohort** is the set of requests admitted together: one prefill
call, position-aligned thereafter (every row advances one token per
iteration).  Cohorts are how iteration-level scheduling meets the
model API's static shapes — caches carry a single shared length
scalar, so joiners get their own cache rows at their own positions
instead of being scattered into a misaligned one.  Rows are
numerically independent under greedy decoding, which is why the
continuous path is bit-exact against one-shot ``generate`` for the
same request set (asserted in tests/test_serving.py).

Time is **virtual**: the clock advances by planner-predicted phase
times from a :class:`~repro.serving.admission.PlannerProbe` (falling
back to measured wall when an engine runs without a probe), so the
whole tier is deterministic and CPU-simulation-testable.  With
``engine=None`` no tokens are computed at all — pure scheduling
simulation, what ``bench_serving`` sweeps and the stress soak drives.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.plan import batch_bucket
from repro.serving.admission import AdmissionController
from repro.serving.queue import CLASS_TTFT_SLACK, Request, RequestQueue


def _metrics():
    from repro.telemetry import metrics as _m
    return _m.default_registry()


def _pctl(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); nan when empty."""
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(np.ceil(q / 100.0 * len(vs))) - 1))
    return vs[idx]


@dataclasses.dataclass
class _Cohort:
    requests: List[Request]
    state: object = None          # engine cohort state (None in sim mode)
    pending: object = None        # last sampled tokens, next decode input

    @property
    def live(self) -> int:
        return sum(1 for r in self.requests if not r.done)

    @property
    def finished(self) -> bool:
        return all(r.done for r in self.requests)


class BatchScheduler:
    """Continuous-batching scheduler over a request queue.

    ``engine``: optional ServeEngine-compatible object providing
    ``start_cohort(prompts, max_new, seed)`` and
    ``step_cohort(state, tokens)``; None = pure scheduling simulation.
    ``probe``: optional PlannerProbe supplying virtual step times (and
    SLO denominators).  ``binder``/``plan_for_bucket``: the plan-prefetch
    seam — admission decisions that cross a batch bucket stage the
    bucket's plan so the swap at the next iteration is warm.
    """

    def __init__(self, *, queue: RequestQueue,
                 admission: AdmissionController,
                 engine=None, probe=None, binder=None,
                 plan_for_bucket: Optional[Callable] = None,
                 static_batching: bool = False,
                 eos_id: Optional[int] = None, seed: int = 0,
                 max_iterations: int = 1_000_000) -> None:
        self.queue = queue
        self.admission = admission
        self.engine = engine
        self.probe = probe
        self.binder = binder
        self.plan_for_bucket = plan_for_bucket
        self.static_batching = static_batching
        self.eos_id = eos_id
        self.seed = seed
        self.max_iterations = max_iterations
        self.now = 0.0
        self.step_time_scale = 1.0      # soak harness: degraded-fabric stall
        self.cohorts: List[_Cohort] = []
        self.completed: List[Request] = []
        self.iterations = 0
        self.max_in_flight = 0
        self.prefetch_rebinds = 0
        self.bound_bucket: Optional[int] = None
        self._staged_bucket: Optional[int] = None
        self.wall = {"prefill_s": 0.0, "decode_s": 0.0}

    # -- introspection -------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return sum(c.live for c in self.cohorts)

    @property
    def idle(self) -> bool:
        return not self.cohorts and not len(self.queue)

    # -- plan staging --------------------------------------------------------
    def _stage_bucket(self, bucket: int) -> None:
        if self.binder is None or self.plan_for_bucket is None:
            self.bound_bucket = bucket   # tracked, nothing to build
            return
        plan = self.plan_for_bucket(bucket)
        if plan is None:
            self.bound_bucket = bucket
            return
        if self.binder.stage(plan):
            self._staged_bucket = bucket
            self.prefetch_rebinds += 1
            _metrics()["repro_plan_prefetch_total"].inc(
                program=plan.program.name)
        else:
            self.bound_bucket = bucket   # already active

    # -- the iteration -------------------------------------------------------
    def step(self) -> bool:
        """One scheduling iteration; False when fully idle (queue empty
        and nothing in flight)."""
        self.iterations += 1
        # (1) step boundary: staged bucket/failover plans land here
        if self.binder is not None and self.binder.swap_if_pending():
            if self._staged_bucket is not None:
                self.bound_bucket = self._staged_bucket
                self._staged_bucket = None
        # (2) admission
        joiners: List[Request] = []
        ready = self.queue.ready_count(self.now)
        barrier = self.static_batching and bool(self.cohorts)
        if ready and not barrier:
            dec = self.admission.decide(
                in_flight=self.in_flight, ready=ready,
                oldest_wait_s=self.queue.oldest_wait_s(self.now),
                bound_bucket=self.bound_bucket)
            if dec.stage_bucket is not None:
                self._stage_bucket(dec.stage_bucket)
            if dec.admit > 0:
                joiners = self.queue.pop_ready(self.now, dec.admit)
        if not joiners and not self.cohorts:
            nxt = self.queue.next_arrival_s(self.now)
            if nxt is None:
                return False
            self.now = max(self.now, nxt)   # idle: jump to next arrival
            return True
        old_cohorts = list(self.cohorts)
        dt = 0.0
        # (3) prefill the joining cohort while the others decode
        if joiners:
            dt += self._admit(joiners)
        # (4) one decode round over the in-flight cohorts
        if old_cohorts:
            dt += self._decode_round(old_cohorts)
        self.now += dt
        self._finalize()
        reg = _metrics()
        reg["repro_serving_queue_depth"].set(self.queue.ready_count(self.now))
        reg["repro_serving_in_flight"].set(self.in_flight)
        self.max_in_flight = max(self.max_in_flight, self.in_flight)
        return True

    def _admit(self, joiners: List[Request]) -> float:
        n = len(joiners)
        prompt_len = joiners[0].prompt_len
        if any(r.prompt_len != prompt_len for r in joiners):
            raise ValueError("one cohort = one prompt_len (pad upstream)")
        in_flight_after = self.in_flight + n
        for r in joiners:
            r.admit_s = self.now
            if self.probe is not None:
                r.predicted_ttft_s = self.probe.prefill_s(n, prompt_len)
                r.predicted_tpot_s = self.probe.decode_step_s(
                    in_flight_after)
        if self.bound_bucket is None or self.static_batching:
            # first admission (or a fresh static batch): the plan bound
            # at startup covers this bucket
            self.bound_bucket = batch_bucket(max(1, in_flight_after))
        cohort = _Cohort(requests=joiners)
        dt = 0.0
        if self.engine is not None:
            prompts = np.stack([np.asarray(r.prompt, np.int32)
                                for r in joiners])
            state, toks, wall = self.engine.start_cohort(
                prompts, max_new=max(r.max_new for r in joiners),
                seed=self.seed)
            cohort.state = state
            cohort.pending = toks
            self.wall["prefill_s"] += wall
            if self.probe is None:
                dt = wall
        if self.probe is not None:
            dt = self.probe.prefill_s(n, prompt_len) * self.step_time_scale
        self._emit(cohort, cohort.pending)
        self.cohorts.append(cohort)
        _metrics()["repro_requests_total"].inc(n, outcome="admitted")
        return dt

    def _decode_round(self, cohorts: List[_Cohort]) -> float:
        dt = 0.0
        total = sum(c.live for c in cohorts)   # payload BEFORE finishes
        for cohort in cohorts:
            if self.engine is not None:
                state, toks, wall = self.engine.step_cohort(
                    cohort.state, cohort.pending)
                cohort.state = state
                cohort.pending = toks
                self.wall["decode_s"] += wall
                if self.probe is None:
                    dt += wall
                self._emit(cohort, toks)
            else:
                self._emit(cohort, None)
        if self.probe is not None:
            if total > 0:
                dt = self.probe.decode_step_s(
                    total, bound_batch=self.bound_bucket) * \
                    self.step_time_scale
        return dt

    def _emit(self, cohort: _Cohort, tokens) -> None:
        """Credit one emitted token per live row (timestamps land in
        :meth:`_finalize`, after the iteration's dt is on the clock)."""
        for i, req in enumerate(cohort.requests):
            if req.done:
                continue
            tok = None if tokens is None else int(tokens[i])
            if tok is not None:
                req.tokens.append(tok)
            req.emitted += 1
            if req.first_token_s is None:
                req.first_token_s = -1.0   # sentinel: stamp in _finalize
            if tok is not None and self.eos_id is not None and \
                    tok == self.eos_id:
                req.eos = True

    def _finalize(self) -> None:
        """Stamp this iteration's emissions/finishes at the advanced
        clock and retire fully-done cohorts."""
        keep = []
        for cohort in self.cohorts:
            for req in cohort.requests:
                if req.first_token_s == -1.0:
                    req.first_token_s = self.now
                if req.done and req.finish_s is None:
                    req.finish_s = self.now
                    self._complete(req)
            if cohort.finished:
                continue    # exit: capacity released this boundary
            keep.append(cohort)
        self.cohorts = keep

    def _complete(self, req: Request) -> None:
        self.completed.append(req)
        reg = _metrics()
        reg["repro_requests_total"].inc(outcome="completed")
        if req.queue_wait_s is not None:
            reg["repro_request_queue_wait_seconds"].observe(req.queue_wait_s)
        if req.ttft_s is not None:
            reg["repro_request_ttft_seconds"].observe(req.ttft_s)
        if req.tpot_s is not None:
            reg["repro_request_tpot_seconds"].observe(req.tpot_s)
        if req.predicted_ttft_s is not None:
            from repro.telemetry import slo as _slo
            _slo.observe_request(
                {"ttft": req.ttft_s, "tpot": req.tpot_s},
                {"ttft": req.predicted_ttft_s, "tpot": req.predicted_tpot_s},
                slack=CLASS_TTFT_SLACK.get(req.slo_class, 1.0))

    # -- drivers -------------------------------------------------------------
    def run_until_drained(self) -> "BatchScheduler":
        """Run until the queue is empty and every cohort retired."""
        for _ in range(self.max_iterations):
            if not self.step():
                return self
        raise RuntimeError(f"scheduler did not drain within "
                           f"{self.max_iterations} iterations")

    def run_for(self, duration_s: float) -> "BatchScheduler":
        """Advance the virtual clock by ``duration_s`` (the soak
        harness's per-epoch window); returns early when fully idle."""
        t_end = self.now + duration_s
        for _ in range(self.max_iterations):
            if self.now >= t_end:
                return self
            if not self.step():
                self.now = t_end
                return self
        raise RuntimeError("run_for exceeded max_iterations")

    # -- reporting -----------------------------------------------------------
    def report(self, *, ttft_slo_s: Optional[float] = None,
               tpot_slo_s: Optional[float] = None) -> dict:
        ttfts = [r.ttft_s for r in self.completed if r.ttft_s is not None]
        tpots = [r.tpot_s for r in self.completed if r.tpot_s is not None]
        waits = [r.queue_wait_s for r in self.completed
                 if r.queue_wait_s is not None]
        out = {
            "completed": len(self.completed),
            "pending": len(self.queue),
            "in_flight": self.in_flight,
            "iterations": self.iterations,
            "max_in_flight": self.max_in_flight,
            "horizon_s": self.now,
            "ttft_p50_s": _pctl(ttfts, 50), "ttft_p99_s": _pctl(ttfts, 99),
            "tpot_p50_s": _pctl(tpots, 50), "tpot_p99_s": _pctl(tpots, 99),
            "queue_wait_p99_s": _pctl(waits, 99),
            "prefetch_rebinds": self.prefetch_rebinds,
            "admission_holds": self.admission.holds,
            "admission_rejects": dict(self.admission.rejected),
        }
        if self.binder is not None:
            out["plan_swaps"] = self.binder.swaps
            out["cold_retraces"] = self.binder.cold_retraces
        if ttft_slo_s is not None or tpot_slo_s is not None:
            good = [r for r in self.completed
                    if (ttft_slo_s is None or (r.ttft_s or 0.0)
                        <= ttft_slo_s * CLASS_TTFT_SLACK.get(r.slo_class, 1.0))
                    and (tpot_slo_s is None or r.tpot_s is None
                         or r.tpot_s <= tpot_slo_s)]
            out["slo_good"] = len(good)
            out["goodput_rps"] = (len(good) / self.now if self.now > 0
                                  else 0.0)
        return out
