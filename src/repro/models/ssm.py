"""Mamba2 blocks + the Zamba2 hybrid stack.

Zamba2 interleaves a backbone of Mamba2 (SSD) blocks with a SHARED
attention+MLP block applied every ``shared_attn_every`` layers (weight
sharing is Zamba's signature trick — the same global block re-reads the
residual stream at multiple depths).  The released model alternates two
shared blocks with per-invocation LoRA deltas; we implement one shared
block (see DESIGN.md §Arch-fidelity).

Mamba2 block:  in_proj -> (z gate, x, B, C, dt) -> causal depthwise conv
on x -> SSD scan (Pallas kernel / jnp ref) -> z-gated RMSNorm -> out_proj.

Decode state per layer: conv tail [B, d_inner, conv-1] + SSD state
[B, heads, ds, dh] — O(1) per token, which is why this arch runs the
long_500k cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.parallel.context import shard


def _inner_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads


def init_mamba2_block(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, heads = _inner_dims(cfg)
    ds = cfg.ssm_state
    ks = jax.random.split(key, 6)
    proj_out = 2 * d_inner + 2 * ds + heads   # z, x, B, C, dt
    return {
        "ln": L.init_rmsnorm(d),
        "in_proj": L.truncated_normal(ks[0], (d, proj_out), 1 / math.sqrt(d)),
        "conv": L.truncated_normal(ks[1], (cfg.ssm_conv, d_inner), 0.5),
        "A_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "out_norm": L.init_rmsnorm(d_inner),
        "out_proj": L.truncated_normal(ks[2], (d_inner, d),
                                       1 / math.sqrt(d_inner)),
    }


def _split_proj(proj, cfg, d_inner, heads):
    ds = cfg.ssm_state
    z = proj[..., :d_inner]
    x = proj[..., d_inner:2 * d_inner]
    b = proj[..., 2 * d_inner:2 * d_inner + ds]
    c = proj[..., 2 * d_inner + ds:2 * d_inner + 2 * ds]
    dt = proj[..., 2 * d_inner + 2 * ds:]
    return z, x, b, c, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: [B, S, C]; w: [K, C].
    state: [B, K-1, C] tail from previous tokens (decode) or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out), new_state


def mamba2_block(p, x, cfg, pctx, *, use_pallas=False):
    """Train/prefill.  x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    d_inner, heads = _inner_dims(cfg)
    dh, ds = cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = h @ p["in_proj"].astype(dt_)
    z, xc, bmat, cmat, dt_raw = _split_proj(proj, cfg, d_inner, heads)
    xc, _ = _causal_conv(xc, p["conv"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])             # [B, S, heads]
    a = -jnp.exp(p["A_log"])                          # [heads]
    # head-major layout for the scan kernel: [B*heads, S, dh]
    xh = xc.reshape(b, s, heads, dh).transpose(0, 2, 1, 3).reshape(
        b * heads, s, dh)
    dth = dt.transpose(0, 2, 1).reshape(b * heads, s)
    bh = jnp.broadcast_to(bmat[:, None], (b, heads, s, ds)).reshape(
        b * heads, s, ds)
    ch = jnp.broadcast_to(cmat[:, None], (b, heads, s, ds)).reshape(
        b * heads, s, ds)
    ah = jnp.tile(a, b)
    dskip = jnp.tile(p["D"], b)
    y = ops.mamba2_scan(xh, dth, ah, bh.astype(dt_), ch.astype(dt_), dskip,
                        use_pallas=use_pallas)
    y = y.reshape(b, heads, s, dh).transpose(0, 2, 1, 3).reshape(
        b, s, d_inner)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_)


def mamba2_block_decode(p, x, state, cfg, pctx):
    """One token.  x: [B, 1, D]; state: {"conv": [B,K-1,d_inner],
    "ssd": [B, heads, ds, dh]}."""
    b, _, d = x.shape
    d_inner, heads = _inner_dims(cfg)
    dh, ds = cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = h @ p["in_proj"].astype(dt_)
    z, xc, bmat, cmat, dt_raw = _split_proj(proj, cfg, d_inner, heads)
    xc, conv_state = _causal_conv(xc, p["conv"], state["conv"])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xc[:, 0].reshape(b * heads, dh)
    dth = dt.reshape(b * heads)
    bh = jnp.broadcast_to(bmat[:, 0, None], (b, heads, ds)).reshape(-1, ds)
    ch = jnp.broadcast_to(cmat[:, 0, None], (b, heads, ds)).reshape(-1, ds)
    ssd = state["ssd"].reshape(b * heads, ds, dh)
    ssd, y = ref.mamba2_decode_step(
        ssd, xh.astype(jnp.float32), dth, jnp.tile(a, b),
        bh.astype(jnp.float32), ch.astype(jnp.float32), jnp.tile(p["D"], b))
    y = y.reshape(b, 1, d_inner).astype(dt_)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return (y @ p["out_proj"].astype(dt_),
            {"conv": conv_state, "ssd": ssd.reshape(b, heads, ds, dh)})


# ---------------------------------------------------------------------------
# Zamba2 hybrid stack
# ---------------------------------------------------------------------------

def init_zamba2(key, cfg: ModelConfig):
    from repro.models.transformer import _init_layer
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.init_embedding(ks[1], cfg.vocab, cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "mamba": jax.vmap(
            lambda k: init_mamba2_block(k, cfg))(layer_keys),
        "shared": _init_layer(ks[2], cfg, moe=False),   # ONE shared block
    }


def _shared_positions(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def zamba2_hidden(params, cfg, pctx, x, *, use_pallas=False):
    """Forward through 81 mamba blocks with the shared attn block every
    ``shared_attn_every`` layers.  Grouped scan: scan over groups of
    mamba layers, shared block applied between groups (python loop —
    group count is small)."""
    from repro.models.transformer import _dense_block
    b, s, _ = x.shape
    period = cfg.shared_attn_every
    n = cfg.n_layers
    positions = _shared_positions(b, s)

    def mamba_body(carry, lp):
        def inner(lp_, x_):
            from repro.parallel.context import shard_residual
            return shard_residual(
                x_ + mamba2_block(lp_, x_, cfg, pctx,
                                  use_pallas=use_pallas), pctx)
        from repro.models.transformer import _remat
        return _remat(inner, pctx)(lp, carry), None

    done = 0
    gi = 0
    while done < n:
        take = min(period, n - done)
        group = jax.tree_util.tree_map(
            lambda a: a[done:done + take], params["mamba"])
        x, _ = jax.lax.scan(mamba_body, x, group)
        done += take
        if done < n:
            x, _ = _dense_block(params["shared"], x, positions, cfg, pctx,
                                window=None)
        gi += 1
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), \
        jnp.zeros((), jnp.float32)


def zamba2_init_state(cfg, batch, max_len, dtype=jnp.bfloat16):
    d_inner, heads = _inner_dims(cfg)
    n_shared = max(0, (cfg.n_layers - 1) // cfg.shared_attn_every)
    g, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, d_inner),
                          dtype),
        "ssd": jnp.zeros((cfg.n_layers, batch, heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
        "k": tuple(jnp.zeros((batch, max_len, g, dh), dtype)
                   for _ in range(n_shared)),
        "v": tuple(jnp.zeros((batch, max_len, g, dh), dtype)
                   for _ in range(n_shared)),
        "len": jnp.zeros((), jnp.int32),
    }


def mamba2_block_prefill(p, x, cfg, pctx):
    """Like mamba2_block but returns decode states (conv tail + final SSD
    state) via the chunked-jnp scan."""
    b, s, d = x.shape
    d_inner, heads = _inner_dims(cfg)
    dh, ds = cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = h @ p["in_proj"].astype(dt_)
    z, xc, bmat, cmat, dt_raw = _split_proj(proj, cfg, d_inner, heads)
    xc, conv_tail = _causal_conv(xc, p["conv"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xc.reshape(b, s, heads, dh).transpose(0, 2, 1, 3).reshape(
        b * heads, s, dh)
    dth = dt.transpose(0, 2, 1).reshape(b * heads, s)
    bh = jnp.broadcast_to(bmat[:, None], (b, heads, s, ds)).reshape(
        b * heads, s, ds)
    ch = jnp.broadcast_to(cmat[:, None], (b, heads, s, ds)).reshape(
        b * heads, s, ds)
    y, hf = ref.mamba2_chunked_jnp(
        xh, dth, jnp.tile(a, b), bh.astype(dt_), ch.astype(dt_),
        jnp.tile(p["D"], b), return_final=True)
    y = y.reshape(b, heads, s, dh).transpose(0, 2, 1, 3).reshape(
        b, s, d_inner)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    return out, {"conv": conv_tail, "ssd": hf.reshape(b, heads, ds, dh)}


def zamba2_prefill(params, cfg, pctx, x, state):
    """Prefill the hybrid stack, capturing every layer's decode state."""
    from repro.models.transformer import _attn_part, _ffn_part
    b, s, _ = x.shape
    period = cfg.shared_attn_every
    n = cfg.n_layers
    positions = _shared_positions(b, s)
    conv_s, ssd_s = state["conv"], state["ssd"]
    ks, vs = list(state["k"]), list(state["v"])

    def mamba_body(x, lp):
        y, st = mamba2_block_prefill(lp, x, cfg, pctx)
        return x + y, st

    done = si = 0
    while done < n:
        take = min(period, n - done)
        group = jax.tree_util.tree_map(
            lambda a: a[done:done + take], params["mamba"])
        x, sts = jax.lax.scan(mamba_body, x, group)
        conv_s = jax.lax.dynamic_update_slice(
            conv_s, sts["conv"].astype(conv_s.dtype), (done, 0, 0, 0))
        ssd_s = jax.lax.dynamic_update_slice(
            ssd_s, sts["ssd"], (done, 0, 0, 0, 0))
        done += take
        if done < n:
            a, (k, v) = _attn_part(params["shared"], x, positions, cfg,
                                   pctx, window=None, return_kv=True)
            x = x + a
            f, _ = _ffn_part(params["shared"], x, cfg, pctx)
            x = x + f
            pad = ks[si].shape[1] - k.shape[1]
            if pad:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            ks[si] = k.astype(ks[si].dtype)
            vs[si] = v.astype(vs[si].dtype)
            si += 1
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"conv": conv_s, "ssd": ssd_s, "k": tuple(ks), "v": tuple(vs),
               "len": jnp.asarray(s, jnp.int32)}


def zamba2_decode_step(params, cfg, pctx, x, state):
    """One token through the hybrid stack."""
    from repro.models.transformer import _decode_attn, _ffn_part
    period = cfg.shared_attn_every
    n = cfg.n_layers
    cur = state["len"]
    conv_s, ssd_s = state["conv"], state["ssd"]
    ks, vs = list(state["k"]), list(state["v"])
    si = 0
    for li in range(n):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["mamba"])
        y, new_s = mamba2_block_decode(
            lp, x, {"conv": conv_s[li], "ssd": ssd_s[li]}, cfg, pctx)
        x = x + y
        conv_s = conv_s.at[li].set(new_s["conv"].astype(conv_s.dtype))
        ssd_s = ssd_s.at[li].set(new_s["ssd"].astype(ssd_s.dtype))
        if (li + 1) % period == 0 and li + 1 < n:
            a, ck, cv = _decode_attn(params["shared"], x, ks[si], vs[si],
                                     cur, cfg, pctx, window=None)
            x = x + a
            f, _ = _ffn_part(params["shared"], x, cfg, pctx)
            x = x + f
            ks[si], vs[si] = ck, cv
            si += 1
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"conv": conv_s, "ssd": ssd_s, "k": tuple(ks), "v": tuple(vs),
               "len": cur + 1}
