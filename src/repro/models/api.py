"""Unified model API over all architecture families.

``build_model(cfg, pctx)`` returns a :class:`Model` whose members are pure
functions (jit-able, shardable):

  init(rng)                      -> params
  loss(params, batch)            -> (scalar loss, metrics)
  prefill(params, batch, cache)  -> (next-token logits [B, V], cache)
  decode(params, batch, cache)   -> (logits [B, V], cache)
  init_cache(batch, max_len)     -> cache pytree (zeros; dry-run uses
                                    eval_shape on this)

Batch formats (kind -> keys):
  tokens     {"tokens" [B,S] i32, "labels" [B,S] i32}
  embeddings {"embeds" [B,S,D], "positions" [B,S,3] i32, "labels" [B,S]}
             (qwen2-vl stub frontend)
  encdec     {"src_embeds" [B,S,D], "tgt_tokens" [B,S], "labels" [B,S]}
             (seamless stub frontend)
  decode     {"tokens" [B,1]} (or {"embeds" [B,1,D]} for qwen2-vl)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rwkv, ssm, transformer as T
from repro.parallel.context import ParallelContext

Params = Any
Batch = dict
Cache = dict


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    pctx: Optional[ParallelContext]
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def _positions_for(cfg, b, s):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def build_model(cfg: ModelConfig, pctx: Optional[ParallelContext] = None,
                *, use_kernels: bool = False,
                dtype=jnp.bfloat16) -> Model:
    fam = cfg.family

    # ---- init ---------------------------------------------------------------
    if fam in ("dense", "moe", "encdec"):
        init = lambda key: T.init_transformer(key, cfg)      # noqa: E731
    elif fam == "hybrid":
        init = lambda key: ssm.init_zamba2(key, cfg)         # noqa: E731
    elif fam == "rwkv":
        init = lambda key: rwkv.init_rwkv6(key, cfg)         # noqa: E731
    else:
        raise ValueError(fam)

    # ---- embedding of inputs --------------------------------------------------
    def embed_in(params, batch):
        if cfg.input_mode == "embeddings" and "embeds" in batch:
            x = batch["embeds"].astype(dtype)
            pos = batch.get(
                "positions",
                _positions_for(cfg, x.shape[0], x.shape[1]))
            return x, pos
        toks = batch["tokens"]
        x = L.embed(params["embed"], toks, dtype)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)  # gemma-style
        return x, _positions_for(cfg, toks.shape[0], toks.shape[1])

    # ---- hidden-stack dispatch -------------------------------------------------
    def hidden_train(params, batch):
        if fam == "encdec":
            enc_out = T.encode(params, cfg, pctx,
                               batch["src_embeds"].astype(dtype))
            tgt = L.embed(params["embed"], batch["tgt_tokens"], dtype)
            b, s = batch["tgt_tokens"].shape
            pos = _positions_for(cfg, b, s)
            h = T.forward_hidden_encdec(params, cfg, pctx, tgt, pos, enc_out)
            return h, jnp.zeros((), jnp.float32)
        x, pos = embed_in(params, batch)
        if fam == "hybrid":
            return ssm.zamba2_hidden(params, cfg, pctx, x,
                                     use_pallas=use_kernels)
        if fam == "rwkv":
            return rwkv.rwkv6_hidden(params, cfg, pctx, x,
                                     use_pallas=use_kernels)
        return T.forward_hidden(params, cfg, pctx, x, pos)

    # ---- loss -------------------------------------------------------------------
    def loss(params, batch):
        h, aux = hidden_train(params, batch)
        if "unembed" in params:
            w, tied = params["unembed"]["w"], False
        else:
            w, tied = params["embed"]["emb"], True
        ce = L.chunked_cross_entropy(h, w, batch["labels"], tied=tied,
                                     final_softcap=cfg.final_softcap)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ---- caches -------------------------------------------------------------------
    def init_cache(batch, max_len, cache_dtype=jnp.bfloat16):
        if fam == "hybrid":
            return ssm.zamba2_init_state(cfg, batch, max_len, cache_dtype)
        if fam == "rwkv":
            return rwkv.rwkv6_init_state(cfg, batch, cache_dtype)
        if fam == "encdec":
            g, dh = cfg.n_kv_heads, cfg.head_dim
            return {
                "k": tuple(jnp.zeros((batch, max_len, g, dh), cache_dtype)
                           for _ in range(cfg.n_layers)),
                "v": tuple(jnp.zeros((batch, max_len, g, dh), cache_dtype)
                           for _ in range(cfg.n_layers)),
                "len": jnp.zeros((), jnp.int32),
                "enc_out": jnp.zeros((batch, max_len, cfg.d_model),
                                     cache_dtype),
            }
        return T.init_cache(cfg, batch, max_len, cache_dtype)

    # ---- prefill ---------------------------------------------------------------------
    def prefill(params, batch, cache):
        if fam == "encdec":
            tgt = L.embed(params["embed"], batch["tgt_tokens"], dtype)
            b, s = batch["tgt_tokens"].shape
            pos = _positions_for(cfg, b, s)
            logits, cache = T.prefill_encdec(
                params, cfg, pctx, batch["src_embeds"].astype(dtype), tgt,
                pos, cache)
            return logits[:, 0], cache
        x, pos = embed_in(params, batch)
        if fam == "hybrid":
            h, cache = ssm.zamba2_prefill(params, cfg, pctx, x, cache)
            return T.logits_fn(params, cfg, h, last_only=True)[:, 0], cache
        if fam == "rwkv":
            h, cache = rwkv.rwkv6_prefill(params, cfg, pctx, x, cache)
            return T.logits_fn(params, cfg, h, last_only=True)[:, 0], cache
        logits, cache = T.prefill(params, cfg, pctx, x, pos, cache)
        return logits[:, 0], cache

    # ---- decode ----------------------------------------------------------------------
    def decode(params, batch, cache):
        if cfg.input_mode == "embeddings" and "embeds" in batch:
            x = batch["embeds"].astype(dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"], dtype)
            if cfg.tie_embeddings:
                x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
        if fam == "encdec":
            logits, cache = T.decode_step_encdec(params, cfg, pctx, x, cache)
            return logits[:, 0], cache
        if fam == "hybrid":
            h, cache = ssm.zamba2_decode_step(params, cfg, pctx, x, cache)
            return T.logits_fn(params, cfg, h, last_only=True)[:, 0], cache
        if fam == "rwkv":
            h, cache = rwkv.rwkv6_decode_step(params, cfg, pctx, x, cache)
            return T.logits_fn(params, cfg, h, last_only=True)[:, 0], cache
        logits, cache = T.decode_step(params, cfg, pctx, x, cache)
        return logits[:, 0], cache

    return Model(cfg=cfg, pctx=pctx, init=init, loss=loss, prefill=prefill,
                 decode=decode, init_cache=init_cache)


# ---------------------------------------------------------------------------
# synthetic batch builders (smoke tests + data pipeline + dry-run specs)
# ---------------------------------------------------------------------------

def make_batch(cfg: ModelConfig, kind: str, batch: int, seq: int,
               rng_seed: int = 0):
    """Concrete synthetic batch (smoke tests / examples)."""
    import numpy as np
    rng = np.random.default_rng(rng_seed)
    toks = rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
    labels = np.roll(toks, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1
    if kind == "decode":
        if cfg.input_mode == "embeddings" and cfg.family != "encdec":
            return {"embeds": jnp.asarray(
                rng.normal(size=(batch, 1, cfg.d_model)).astype(np.float32))}
        return {"tokens": jnp.asarray(toks[:, :1])}
    if cfg.family == "encdec":
        emb = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
        return {"src_embeds": jnp.asarray(emb),
                "tgt_tokens": jnp.asarray(toks),
                "labels": jnp.asarray(labels)}
    if cfg.input_mode == "embeddings":
        emb = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None, :, None],
                              (batch, seq, 3)).copy()
        return {"embeds": jnp.asarray(emb), "positions": jnp.asarray(pos),
                "labels": jnp.asarray(labels)}
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def param_count_shape_only(cfg: ModelConfig) -> int:
    """Parameter count WITHOUT allocation (eval_shape on init)."""
    import math
    shapes = jax.eval_shape(
        lambda k: build_model(cfg).init(k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(shapes))