"""Shared neural layers: norms, RoPE/M-RoPE, MLPs, GQA attention, caches.

Pure-functional: params are nested dicts of jnp arrays; every layer is a
``(params, x, ...) -> y`` function plus an ``init_*`` constructor.  Compute
dtype is the input dtype (bf16 in production configs); params are stored
in fp32 and cast at use.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.context import shard

DEFAULT_DTYPE = jnp.bfloat16


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"w": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + p["w"])).astype(dt)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=1e4,
               mrope_sections: Optional[tuple] = None):
    """Rotary embedding.

    x: [B, S, H, D]; positions: [B, S] int — or [B, S, 3] when
    ``mrope_sections`` is given (qwen2-vl M-RoPE: the head-dim halves are
    split into (t, h, w) sections, each rotated by its own position id).
    """
    b, s, h, d = x.shape
    inv = rope_freqs(d, theta)                               # [d/2]
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv  # [B,S,d/2]
    else:
        assert sum(mrope_sections) == d // 2, (mrope_sections, d)
        parts = []
        off = 0
        for sec_i, sec in enumerate(mrope_sections):
            p = positions[..., sec_i].astype(jnp.float32)    # [B,S]
            parts.append(p[..., None] * inv[off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)                # [B,S,d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# streaming (flash) attention in pure jnp — the scan-friendly production
# fallback; supports TRACED window sizes (gemma2 alternating layers under
# lax.scan).  Oracle-equivalent to kernels/ref.attention_ref.
# ---------------------------------------------------------------------------

def flash_attention_jnp(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None, block_k=1024):
    """GQA-aware streaming attention.

    q: [B, H, S, D]; k/v: [B, G, T, D] with H = G * rep (grouped heads —
    NO materialized kv broadcast).  Dots run on the input dtype with fp32
    accumulation (``preferred_element_type``) — no fp32 copies of q/k/v.
    window may be a traced scalar.  Returns [B, H, S, D] in q.dtype.
    """
    b, h, sq, d = q.shape
    g, t = k.shape[1], k.shape[2]
    rep = h // g
    qg = q.reshape(b, g, rep, sq, d)
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, t)
    nb = (t + block_k - 1) // block_k
    pad = nb * block_k - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, g, nb, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, g, nb, block_k, d).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, bi = inp
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = bi * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < t
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bgkd->bgrqd", p.astype(q.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, g, rep, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, g, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, g, rep, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kb, vb, jnp.arange(nb)))
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None],
                    0.0)
    return out.reshape(b, h, sq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int


def init_attention(key, dims: AttnDims):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, g, dh = dims.d_model, dims.n_heads, dims.n_kv, dims.d_head
    sc = 1.0 / math.sqrt(d)
    return {
        "wq": truncated_normal(kq, (d, h * dh), sc),
        "wk": truncated_normal(kk, (d, g * dh), sc),
        "wv": truncated_normal(kv, (d, g * dh), sc),
        "wo": truncated_normal(ko, (h * dh, d), 1.0 / math.sqrt(h * dh)),
    }


def attention_specs(pctx, fsdp: bool):
    """PartitionSpecs matching init_attention params (col/col/col/row TP)."""
    from jax.sharding import PartitionSpec as P
    fs = pctx.data_axis if fsdp else None
    return {"wq": P(fs, pctx.model_axis), "wk": P(fs, pctx.model_axis),
            "wv": P(fs, pctx.model_axis), "wo": P(pctx.model_axis, fs)}


def attention(p, x, positions, dims: AttnDims, pctx, *, causal=True,
              window=None, softcap=None, rope_theta=1e4, mrope=None,
              use_pallas=False, return_kv=False):
    """Training/prefill attention.  x: [B, S, D]."""
    b, s, d = x.shape
    h, g, dh = dims.n_heads, dims.n_kv, dims.d_head
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, g, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, g, dh)
    q = apply_rope(q, positions, rope_theta, mrope)
    k = apply_rope(k, positions, rope_theta, mrope)
    kv = (k, v) if return_kv else None
    if pctx is not None:
        # Megatron GQA sharding: q heads over model; kv heads over model
        # only when divisible, else REPLICATED (g < tp).  Without the
        # explicit kv constraint the partitioner ping-pongs between
        # (g-split, d-split) layouts fwd vs bwd and re-gathers the full
        # fp32 score tensor every kv block (8 GiB x 240 on kimi-k2).
        q = shard(q, pctx, pctx.dp_axes, None, pctx.model_axis, None)
        g_ax = (pctx.model_axis if g % pctx.model_size == 0 else None)
        k = shard(k, pctx, pctx.dp_axes, None, g_ax, None)
        v = shard(v, pctx, pctx.dp_axes, None, g_ax, None)
    qt = q.transpose(0, 2, 1, 3)            # [B, H, S, dh]
    kt = k.transpose(0, 2, 1, 3)            # [B, G, S, dh]
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas and (window is None or isinstance(window, int)):
        rep = h // g
        kx = jnp.repeat(kt, rep, axis=1)    # kernel path takes matched heads
        vx = jnp.repeat(vt, rep, axis=1)
        o = ops.flash_attention(
            qt.reshape(b * h, s, dh), kx.reshape(b * h, s, dh),
            vx.reshape(b * h, s, dh), causal=causal, window=window,
            softcap=softcap).reshape(b, h, s, dh)
    else:
        o = flash_attention_jnp(qt, kt, vt, causal=causal, window=window,
                                softcap=softcap)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    out = o @ p["wo"].astype(dt)
    if pctx is not None:
        out = shard(out, pctx, pctx.dp_axes, None, None)
    return (out, kv) if return_kv else out


def decode_attention_block(p, x, cache_k, cache_v, cur_len, dims: AttnDims,
                           pctx, *, window=None, softcap=None,
                           rope_theta=1e4, mrope=None):
    """Single-token decode.  x: [B, 1, D]; cache_[kv]: [B, Smax, g, dh];
    cur_len: scalar int (tokens already in cache).  Returns
    (out [B,1,D], cache_k, cache_v updated)."""
    b, _, d = x.shape
    h, g, dh = dims.n_heads, dims.n_kv, dims.d_head
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, 1, h, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, 1, g, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, 1, g, dh)
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    if mrope is not None:
        pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
    q = apply_rope(q, pos, rope_theta, mrope)
    k = apply_rope(k, pos, rope_theta, mrope)
    if pctx is not None:
        # flash-decoding style: KV length sharded over model (cache spec);
        # the single-token q is tiny — replicate it over model so the
        # score einsum contracts against the length-sharded cache without
        # a batch reshard (softmax over the sharded length reduces via
        # all-reduce of max/sum).
        mdl = pctx.model_axis if pctx.seq_shard_decode else None
        q = shard(q, pctx, pctx.dp_axes, None,
                  None if mdl else pctx.model_axis, None)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, cur_len, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, cur_len, 0, 0))
    o = ops.decode_attention(
        q[:, 0], cache_k, cache_v,
        kv_len=cur_len + 1, softcap=softcap, window=window)
    o = o.reshape(b, 1, h * dh).astype(dt)
    if pctx is not None:
        o = shard(o, pctx, pctx.dp_axes, None, None)
    return o @ p["wo"].astype(dt), cache_k, cache_v


# ---------------------------------------------------------------------------
# split-TP AllGather (§3.1) — the tp_subgroups > 1 activation gather
# ---------------------------------------------------------------------------

def split_tp_allgather(x, pctx, *, axis_name: Optional[str] = None):
    """AllGather a model-axis-sharded activation across its split-TP
    domain (paper §3.1: the model axis divided into ``pctx.tp_subgroups``
    TP domains, cross-domain links idle and available for relaying).

    Must be called inside ``shard_map`` (named-axis collective).  Routing:

    - bound ``pctx.execution_plan`` with a matching declared allgather
      site, or ``plan_policy == "auto"``: the per-site decision comes
      from ``pctx.allgather_plan`` (ExecutionPlan lookup first, planner
      fallback — baseline below the Fig 7 crossover, multiwrite above
      it); no fixed ``mode=``/``split=`` at the call site.
    - ``plan_policy == "fixed"`` without a bound site: the
      paper-faithful multiwrite paired relaying at the §5.2 analytic
      split.
    - ``tp_subgroups == 1``: plain all_gather over the whole axis (no
      split-TP domains, nothing to relay through).

    Returns ``[domain_size, *x.shape]`` — fragment-stacked, bit-identical
    to ``collectives.allgather_reference`` over the same domains.
    """
    import math as _math

    from repro.core import collectives as cl
    from repro.core.schedules import optimal_split

    axis = axis_name or pctx.model_axis
    nd = pctx.tp_subgroups
    if nd <= 1:
        return cl.allgather_reference(x, axis, num_domains=1)
    if nd != 2:
        # paired relaying (and the registered §3.1 plans) are defined on
        # 2 domains; more domains gather plainly within each domain
        return cl.allgather_reference(x, axis, num_domains=nd)
    frag_bytes = _math.prod(x.shape) * x.dtype.itemsize
    decision = pctx.allgather_plan(frag_bytes, num_domains=nd)
    if decision is not None:
        return cl.planned_allgather(x, axis, num_domains=nd,
                                    decision=decision)
    return cl.multiwrite_allgather(
        x, axis, num_domains=nd,
        split=optimal_split("multiwrite_paired"), mode="paired")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, gated: bool):
    ks = jax.random.split(key, 3)
    p = {"w1": truncated_normal(ks[0], (d, f), 1.0 / math.sqrt(d)),
         "w2": truncated_normal(ks[1], (f, d), 1.0 / math.sqrt(f))}
    if gated:
        p["w3"] = truncated_normal(ks[2], (d, f), 1.0 / math.sqrt(d))
    return p


def mlp_specs(pctx, gated: bool, fsdp: bool):
    from jax.sharding import PartitionSpec as P
    fs = pctx.data_axis if fsdp else None
    p = {"w1": P(fs, pctx.model_axis), "w2": P(pctx.model_axis, fs)}
    if gated:
        p["w3"] = P(fs, pctx.model_axis)
    return p


def mlp(p, x, act_name: str, pctx=None):
    dt = x.dtype
    act = activation(act_name)
    hidden = act(x @ p["w1"].astype(dt))
    if "w3" in p:
        hidden = hidden * (x @ p["w3"].astype(dt))
    if pctx is not None:
        hidden = shard(hidden, pctx, pctx.dp_axes, None, pctx.model_axis)
    return hidden @ p["w2"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"emb": truncated_normal(key, (vocab, d), d ** -0.5)}


def embed(p, tokens, dtype=DEFAULT_DTYPE):
    return p["emb"].astype(dtype)[tokens]


def unembed(p_emb, x, out_proj=None, final_softcap=None):
    """Logits; tied (x @ emb.T) unless out_proj given."""
    dt = x.dtype
    w = (p_emb["emb"].astype(dt).T if out_proj is None
         else out_proj.astype(dt))
    logits = x @ w
    if final_softcap is not None:
        logits = final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / final_softcap).astype(dt)
    return logits


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean token CE in fp32; labels == ignore are masked."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = labels != ignore
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def chunked_cross_entropy(h, emb, labels, *, tied=True, chunk=512,
                          final_softcap=None, ignore: int = -1):
    """Sequence-chunked CE that never materializes [B, S, V] logits.

    The unembed matmul + softmax run per S-chunk under a remat wrapper, so
    both forward AND backward hold one chunk of logits at a time — the
    production answer to fp32-logit memory blowup at long seq x huge vocab.

    h: [B, S, D]; emb: [V, D] (tied=True) or [D, V]; labels: [B, S].
    Returns mean token CE (fp32 scalar).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=ignore)
    nc = h.shape[1] // chunk
    hc = h.reshape(b, nc, chunk, d).swapaxes(0, 1)        # [nc, B, C, D]
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    contract = ((2,), (1,)) if tied else ((2,), (0,))

    @jax.checkpoint
    def chunk_loss(hh, ll):
        dt = hh.dtype
        logits = jax.lax.dot_general(
            hh, emb.astype(dt), (contract, ((), ())))     # [B, C, V]
        lf = logits.astype(jnp.float32)
        if final_softcap is not None:
            lf = final_softcap * jnp.tanh(lf / final_softcap)
        logz = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        mask = ll != ignore
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, inp):
        nll, cnt = carry
        hh, ll = inp
        dn, dc = chunk_loss(hh, ll)
        return (nll + dn, cnt + dc), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (hc, lc))
    return nll / jnp.maximum(cnt, 1)
