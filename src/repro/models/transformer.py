"""Decoder-only and encoder-decoder transformer stacks (dense + MoE).

Covers families: dense (starcoder2, minitron, mistral-nemo, gemma2,
qwen2-vl backbone), moe (dbrx, kimi-k2), encdec (seamless-m4t).

Structure:
  * layers are scanned (``lax.scan`` over stacked params [L, ...]) with an
    optional remat wrapper — HLO stays small for 40-81 layer models;
  * per-layer static variation (gemma2 local/global alternation) rides in
    scan xs as a traced window size;
  * MoE stacks keep ``first_k_dense`` leading layers unscanned;
  * decode carries stacked KV caches through the same scan.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.parallel.context import ParallelContext, shard, shard_residual

BIG_WINDOW = 1 << 30


def _dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


def _remat(fn, pctx):
    if pctx is None or pctx.remat == "none":
        return fn
    if pctx.remat == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, *, moe: bool, cross: bool = False):
    ks = jax.random.split(key, 8)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], _dims(cfg)),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.post_norm:
        p["pn1"] = L.init_rmsnorm(cfg.d_model)
        p["pn2"] = L.init_rmsnorm(cfg.d_model)
    if moe:
        p["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.expert_d_ff,
                              cfg.num_experts)
        if cfg.n_shared_experts:
            p["shared_mlp"] = L.init_mlp(
                ks[2], cfg.d_model,
                cfg.expert_d_ff * cfg.n_shared_experts, cfg.mlp_gated)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    if cross:
        p["lnx"] = L.init_rmsnorm(cfg.d_model)
        p["xattn"] = L.init_attention(ks[4], _dims(cfg))
        if cfg.post_norm:
            p["pnx"] = L.init_rmsnorm(cfg.d_model)
    return p


def init_transformer(key, cfg: ModelConfig):
    keys = jax.random.split(key, 8)
    moe = cfg.is_moe
    n_scan = cfg.n_layers - (cfg.first_k_dense if moe else 0)
    layer_keys = jax.random.split(keys[0], n_scan)
    params = {
        "embed": L.init_embedding(keys[1], cfg.vocab, cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "layers": jax.vmap(
            lambda k: _init_layer(k, cfg, moe=moe))(layer_keys),
    }
    if moe and cfg.first_k_dense:
        params["layers_prefix"] = [
            _init_layer(k, cfg, moe=False)
            for k in jax.random.split(keys[2], cfg.first_k_dense)]
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": L.truncated_normal(
            keys[3], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5)}
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[4], cfg.n_enc_layers)
        dec_keys = jax.random.split(keys[5], cfg.n_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe=False))(enc_keys)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe=False, cross=True))(dec_keys)
    return params


# ---------------------------------------------------------------------------
# per-layer window schedule (gemma2 alternation)
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig, n_layers: int):
    """None if the arch has no windows; else [L] int32 (BIG = global)."""
    if cfg.window is None:
        return None
    if not cfg.local_global_alternating:
        return jnp.full((n_layers,), cfg.window, jnp.int32)
    return jnp.where(jnp.arange(n_layers) % 2 == 0, cfg.window,
                     BIG_WINDOW).astype(jnp.int32)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_part(lp, x, positions, cfg, pctx, *, window, causal=True,
               return_kv=False):
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    out = L.attention(
        lp["attn"], h, positions, _dims(cfg), pctx, causal=causal,
        window=window, softcap=cfg.attn_softcap,
        rope_theta=cfg.rope_theta, mrope=cfg.mrope_sections,
        return_kv=return_kv)
    kv = None
    if return_kv:
        out, kv = out
    if cfg.post_norm:
        out = L.rmsnorm(lp["pn1"], out, cfg.norm_eps)
    return (out, kv) if return_kv else out


def _cross_attention(p, x, enc_out, cfg, pctx):
    """Decoder cross-attention (no rope, no mask)."""
    b, s, d = x.shape
    dims = _dims(cfg)
    h, g, dh = dims.n_heads, dims.n_kv, dims.d_head
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (enc_out @ p["wk"].astype(dt)).reshape(b, -1, g, dh)
    v = (enc_out @ p["wv"].astype(dt)).reshape(b, -1, g, dh)
    o = L.flash_attention_jnp(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return o @ p["wo"].astype(dt)


def _ffn_part(lp, x, cfg, pctx):
    h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        out, aux = M.moe_ffn(lp["moe"], h, cfg, pctx)
        if "shared_mlp" in lp:
            out = out + L.mlp(lp["shared_mlp"], h, cfg.act, pctx)
    else:
        out = L.mlp(lp["mlp"], h, cfg.act, pctx)
    if cfg.post_norm:
        out = L.rmsnorm(lp["pn2"], out, cfg.norm_eps)
    return out, aux


def _split_tp_seq_gather(x, pctx: Optional[ParallelContext]):
    """SP -> TP boundary gather through the §3.1 split-TP AllGather.

    With sequence parallelism the residual enters the block S-sharded
    over the model axis; attention needs the full sequence back.  When
    the model axis is divided into ``tp_subgroups`` domains, that gather
    decomposes hierarchically: each domain reassembles its own sequence
    span via :func:`repro.models.layers.split_tp_allgather` — which
    consumes the bound ExecutionPlan's per-site decision (or the planner
    under "auto"); its multiwrite plans exploit the otherwise-idle
    cross-domain links — then ONE cross-domain gather of
    the domain-assembled chunks completes the sequence.  Bit-identical
    to the implicit single-stage GSPMD gather it replaces (the multidev
    suite pins transformer forward equality against ``tp_subgroups=1``).

    No-op (GSPMD keeps gathering implicitly) when there are no split-TP
    domains or the shapes don't tile the mesh.
    """
    if pctx is None or pctx.tp_subgroups <= 1 or not pctx.seq_parallel:
        return x
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.models import layers as L
    from repro.parallel.compat import shard_map

    m = pctx.model_size
    nd = pctx.tp_subgroups
    b, s, d = x.shape
    dp = pctx.num_pods * pctx.data_size
    if m % nd or s % m or b % dp:
        return x
    h = m // nd                      # chips per TP domain
    axis = pctx.model_axis

    def gather(xl):                  # xl: [B/dp, S/m, D]
        frag = L.split_tp_allgather(xl, pctx)          # [h, B/dp, S/m, D]
        dom = jnp.moveaxis(frag, 0, 1).reshape(
            xl.shape[0], h * xl.shape[1], d)           # this domain's span
        groups = [[dd * h + i for dd in range(nd)] for i in range(h)]
        allg = lax.all_gather(dom, axis, axis_index_groups=groups)
        return jnp.moveaxis(allg, 0, 1).reshape(xl.shape[0], s, d)

    return shard_map(
        gather, mesh=pctx.mesh,
        in_specs=P(pctx.dp_axes, pctx.model_axis, None),
        out_specs=P(pctx.dp_axes, None, None),
        check_vma=False)(x)


def _dense_block(lp, x, positions, cfg, pctx, *, window):
    x = _split_tp_seq_gather(x, pctx)
    a = _attn_part(lp, x, positions, cfg, pctx, window=window)
    x = x + a
    f, aux = _ffn_part(lp, x, cfg, pctx)
    x = x + f
    x = shard_residual(x, pctx)
    return x, aux


# ---------------------------------------------------------------------------
# forward (train) — scanned stack
# ---------------------------------------------------------------------------

def forward_hidden(params, cfg: ModelConfig, pctx, x, positions):
    """Run the (decoder) stack on hidden states x [B,S,D].  Returns
    (hidden, aux_loss_sum)."""
    n_scan = params["layers"]["ln1"]["w"].shape[0]
    wins = window_schedule(cfg, cfg.n_layers)
    aux_total = jnp.zeros((), jnp.float32)

    for lp in params.get("layers_prefix", []):
        x, aux = _dense_block(lp, x, positions, cfg, pctx,
                              window=None if wins is None else wins[0])
        aux_total += aux

    offset = cfg.first_k_dense if cfg.is_moe else 0

    def body(carry, xs):
        x, aux_sum = carry
        lp, win = xs
        blk = functools.partial(_dense_block, cfg=cfg, pctx=pctx)

        def inner(lp_, x_, win_):
            return blk(lp_, x_, positions, window=win_)

        inner = _remat(inner, pctx)
        x, aux = inner(lp, x, win)
        return (x, aux_sum + aux), None

    win_xs = (jnp.full((n_scan,), BIG_WINDOW, jnp.int32) if wins is None
              else wins[offset:])
    (x, aux_total), _ = jax.lax.scan(
        body, (x, aux_total), (params["layers"], win_xs))
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux_total


def logits_fn(params, cfg, x, last_only=False):
    if last_only:
        x = x[:, -1:]
    out_proj = params["unembed"]["w"] if "unembed" in params else None
    return L.unembed(params["embed"], x, out_proj, cfg.final_softcap)


# ---------------------------------------------------------------------------
# encoder (enc-dec only)
# ---------------------------------------------------------------------------

def encode(params, cfg, pctx, src_embeds):
    b, s, d = src_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = src_embeds

    def body(carry, lp):
        def inner(lp_, x_):
            a = _attn_part(lp_, x_, positions, cfg, pctx, window=None,
                           causal=False)
            x_ = x_ + a
            f, _ = _ffn_part(lp_, x_, cfg, pctx)
            return x_ + f

        return _remat(inner, pctx)(lp, carry), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward_hidden_encdec(params, cfg, pctx, tgt_embeds, positions, enc_out):
    def body(carry, lp):
        def inner(lp_, x_):
            a = _attn_part(lp_, x_, positions, cfg, pctx, window=None)
            x_ = x_ + a
            xa = _cross_attention(lp_["xattn"],
                                  L.rmsnorm(lp_["lnx"], x_, cfg.norm_eps),
                                  enc_out, cfg, pctx)
            if cfg.post_norm:
                xa = L.rmsnorm(lp_["pnx"], xa, cfg.norm_eps)
            x_ = x_ + xa
            f, _ = _ffn_part(lp_, x_, cfg, pctx)
            return x_ + f

        return _remat(inner, pctx)(lp, carry), None

    x, _ = jax.lax.scan(body, tgt_embeds, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# prefill / decode (KV caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """KV caches are PER-LAYER tuples (not a stacked [L, ...] array): each
    layer's buffer is updated in place by an unrolled decode step — the
    production serving layout (stacked caches carried through a layer loop
    force XLA loop-carry copies of the full cache every step)."""
    g, dh = cfg.n_kv_heads, cfg.head_dim
    n = cfg.n_layers
    return {
        "k": tuple(jnp.zeros((batch, max_len, g, dh), dtype)
                   for _ in range(n)),
        "v": tuple(jnp.zeros((batch, max_len, g, dh), dtype)
                   for _ in range(n)),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, pctx, x, positions, cache):
    """Forward pass that also fills the cache.  Decoder-only families."""
    wins = window_schedule(cfg, cfg.n_layers)
    seq = x.shape[1]
    cdt = cache["k"][0].dtype
    max_len = cache["k"][0].shape[1]
    new_k, new_v = [], []

    idx = 0
    for lp in params.get("layers_prefix", []):
        a, (k, v) = _attn_part(lp, x, positions, cfg, pctx,
                               window=None if wins is None else wins[idx],
                               return_kv=True)
        x = x + a
        f, _ = _ffn_part(lp, x, cfg, pctx)
        x = x + f
        new_k.append(jax.lax.dynamic_update_slice(
            cache["k"][idx], k.astype(cdt), (0, 0, 0, 0)))
        new_v.append(jax.lax.dynamic_update_slice(
            cache["v"][idx], v.astype(cdt), (0, 0, 0, 0)))
        idx += 1

    offset = cfg.first_k_dense if cfg.is_moe else 0
    n_scan = cfg.n_layers - offset

    def body(x, xs):
        lp, win = xs
        a, (k, v) = _attn_part(lp, x, positions, cfg, pctx, window=win,
                               return_kv=True)
        x = x + a
        f, _ = _ffn_part(lp, x, cfg, pctx)
        return x + f, (k.astype(cdt), v.astype(cdt))

    win_xs = (jnp.full((n_scan,), BIG_WINDOW, jnp.int32) if wins is None
              else wins[offset:])
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], win_xs))
    pad = max_len - seq
    for li in range(n_scan):
        k_full = (jnp.pad(ks[li], ((0, 0), (0, pad), (0, 0), (0, 0)))
                  if pad else ks[li])
        v_full = (jnp.pad(vs[li], ((0, 0), (0, pad), (0, 0), (0, 0)))
                  if pad else vs[li])
        new_k.append(k_full)
        new_v.append(v_full)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x, last_only=True)
    return logits, {"k": tuple(new_k), "v": tuple(new_v),
                    "len": jnp.asarray(seq, jnp.int32)}


def decode_step(params, cfg, pctx, x, cache):
    """One decode token, UNROLLED over layers with per-layer cache buffers
    updated in place (donated) — the production serving structure.
    x: [B, 1, D] hidden input; returns (logits, cache)."""
    wins = window_schedule(cfg, cfg.n_layers)
    cur = cache["len"]
    new_k = list(cache["k"])
    new_v = list(cache["v"])

    idx = 0
    for lp in params.get("layers_prefix", []):
        a, ck, cv = _decode_attn(lp, x, new_k[idx], new_v[idx], cur, cfg,
                                 pctx,
                                 window=None if wins is None else wins[idx])
        x = x + a
        f, _ = _ffn_part(lp, x, cfg, pctx)
        x = x + f
        new_k[idx], new_v[idx] = ck, cv
        idx += 1

    offset = cfg.first_k_dense if cfg.is_moe else 0
    n_scan = cfg.n_layers - offset
    for li in range(n_scan):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["layers"])
        win = None if wins is None else wins[offset + li]
        a, ck, cv = _decode_attn(lp, x, new_k[offset + li],
                                 new_v[offset + li], cur, cfg, pctx,
                                 window=win)
        x = x + a
        f, _ = _ffn_part(lp, x, cfg, pctx)
        x = x + f
        new_k[offset + li], new_v[offset + li] = ck, cv
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x, last_only=True)
    return logits, {"k": tuple(new_k), "v": tuple(new_v), "len": cur + 1}


def _decode_attn(lp, x, ck, cv, cur, cfg, pctx, *, window):
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    out, ck, cv = L.decode_attention_block(
        lp["attn"], h, ck, cv, cur, _dims(cfg), pctx, window=window,
        softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
        mrope=cfg.mrope_sections)
    if cfg.post_norm:
        out = L.rmsnorm(lp["pn1"], out, cfg.norm_eps)
    return out, ck, cv


# ---------------------------------------------------------------------------
# enc-dec serving
# ---------------------------------------------------------------------------

def prefill_encdec(params, cfg, pctx, src_embeds, tgt_embeds, positions,
                   cache):
    """Encode the source once, run the decoder prefix, fill the decoder
    self-attn cache (per-layer tuples) and stash encoder states."""
    enc_out = encode(params, cfg, pctx, src_embeds)
    seq = tgt_embeds.shape[1]
    cdt = cache["k"][0].dtype
    max_len = cache["k"][0].shape[1]

    def body(x, lp):
        a, (k, v) = _attn_part(lp, x, positions, cfg, pctx, window=None,
                               return_kv=True)
        x = x + a
        xa = _cross_attention(lp["xattn"],
                              L.rmsnorm(lp["lnx"], x, cfg.norm_eps),
                              enc_out, cfg, pctx)
        if cfg.post_norm:
            xa = L.rmsnorm(lp["pnx"], xa, cfg.norm_eps)
        x = x + xa
        f, _ = _ffn_part(lp, x, cfg, pctx)
        return x + f, (k.astype(cdt), v.astype(cdt))

    x, (ks, vs) = jax.lax.scan(body, tgt_embeds, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x, last_only=True)
    pad = max_len - seq
    new_k, new_v = [], []
    for li in range(cfg.n_layers):
        kf = (jnp.pad(ks[li], ((0, 0), (0, pad), (0, 0), (0, 0)))
              if pad else ks[li])
        vf = (jnp.pad(vs[li], ((0, 0), (0, pad), (0, 0), (0, 0)))
              if pad else vs[li])
        new_k.append(kf)
        new_v.append(vf)
    enc_full = enc_out.astype(cache["enc_out"].dtype)
    if cache["enc_out"].shape[1] > enc_full.shape[1]:
        enc_full = jnp.pad(
            enc_full, ((0, 0),
                       (0, cache["enc_out"].shape[1] - enc_full.shape[1]),
                       (0, 0)))
    return logits, {"k": tuple(new_k), "v": tuple(new_v),
                    "len": jnp.asarray(seq, jnp.int32),
                    "enc_out": enc_full}


def decode_step_encdec(params, cfg, pctx, x, cache):
    cur = cache["len"]
    enc_out = cache["enc_out"]
    new_k = list(cache["k"])
    new_v = list(cache["v"])
    for li in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["layers"])
        a, ck, cv = _decode_attn(lp, x, new_k[li], new_v[li], cur, cfg,
                                 pctx, window=None)
        x = x + a
        xa = _cross_attention(lp["xattn"],
                              L.rmsnorm(lp["lnx"], x, cfg.norm_eps),
                              enc_out, cfg, pctx)
        if cfg.post_norm:
            xa = L.rmsnorm(lp["pnx"], xa, cfg.norm_eps)
        x = x + xa
        f, _ = _ffn_part(lp, x, cfg, pctx)
        x = x + f
        new_k[li], new_v[li] = ck, cv
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x, last_only=True)
    return logits, {"k": tuple(new_k), "v": tuple(new_v), "len": cur + 1,
                    "enc_out": enc_out}
