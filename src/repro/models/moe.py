"""MoE FFN layer built on the MultiWrite hierarchical dispatch.

Token path per layer (DeepSeek-style EP):

  router -> top-k -> hierarchical_dispatch (stage-1 ONE copy per
  (token, remote pod) over DCN, stage-2 relay replication intra-pod)
  -> per-expert gated FFN (TP over the model axis inside each expert)
  -> hierarchical_combine (relay-side partial reduction on the way back)

Scheme selection: the dispatch scheme, the combine (return-path) scheme
and the pipeline chunk count G are ONE jointly-planned decision
(``pctx.moe_pipeline_kwargs``) — resolved by declared-site lookup
against a bound :class:`~repro.core.plan.ExecutionPlan`, or through the
same ``Planner.plan_program`` joint sweep ad hoc under
``plan_policy="auto"`` (payload size + topology decide — the §5.2
dynamic workflow, Fig 8's batch-dependent winner, with both halves of
the round trip scored as one shared chunk pipeline); under "fixed",
``pctx.moe_scheme``/``pctx.moe_combine`` select hierarchical
(MultiWrite) vs baseline (unicast) verbatim — the paper's comparison
pair, selectable per run for the §Perf ablation.  A hierarchical
dispatch may return via relay-reduced partials (hierarchical_combine)
or individual partials (hierarchical_combine_unicast), whichever the
joint ledger scores faster on the active fabric; G > 1 runs the
double-buffered pipeline below — dispatch of chunk k+1 overlaps expert
FFN of chunk k and combine of chunk k-1, bit-exact vs the G == 1 trace.

EP placement: EP spans (pod, data) when the arch has enough experts
(kimi-k2: 384 experts over 32 EP ranks — the paper's large-EP regime);
otherwise EP = the data axis and pod stays pure DP (dbrx: 16 experts).
Without a mesh (pctx=None) the dispatch degenerates to local packing —
the same code path, zero collectives.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cl
from repro.models import layers as L
from repro.parallel.compat import shard_map
from repro.parallel.context import ParallelContext


def init_moe(key, d: int, f: int, num_experts: int, ep_ranks: int = 1):
    """Router + stacked expert weights [E, ...] (gated FFN)."""
    kr, k1, k2, k3 = jax.random.split(key, 4)
    sc_d, sc_f = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": L.truncated_normal(kr, (d, num_experts), sc_d),
        "w1": L.truncated_normal(k1, (num_experts, d, f), sc_d),
        "w3": L.truncated_normal(k3, (num_experts, d, f), sc_d),
        "w2": L.truncated_normal(k2, (num_experts, f, d), sc_f),
    }


def moe_specs(pctx: ParallelContext, num_experts: int, fsdp: bool):
    """Experts sharded over the EP axes; expert hidden over model (TP)."""
    use_pod, _ = pctx.ep_ranks(num_experts)
    ep = (("pod", "data") if use_pod and pctx.pod_axis
          else (pctx.data_axis,))
    return {
        "router": P(None, None),
        "w1": P(ep, None, pctx.model_axis),
        "w3": P(ep, None, pctx.model_axis),
        "w2": P(ep, pctx.model_axis, None),
    }


def _expert_ffn(w1, w3, w2, x, act_name: str, model_axis: str | None):
    """Per-expert gated FFN on packed buffers x: [E_l, C, D].
    w*: [E_l, D, F_shard] — row-parallel over model_axis (psum inside)."""
    act = L.activation(act_name)
    dt = x.dtype
    h = act(jnp.einsum("ecd,edf->ecf", x, w1.astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", x, w3.astype(dt))
    out = jnp.einsum("ecf,efd->ecd", h, w2.astype(dt))
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out


def balanced_capacities(n_tokens: int, k: int, p: int, d: int,
                        per_rank: int, cf: float) -> cl.DispatchConfig:
    """Capacity factors sized from *balanced-routing expectations* (the
    paper evaluates with load balancing on, §6.1), with headroom ``cf``:

      stage-1 slots/pod     ~ N * min(1, k/p)
      stage-2 slots/ep rank ~ (arrivals p*Cp) * min(1, (k/p)/d)
      expert slots          ~ N*k/per_rank  (total (token,expert) pairs)
    """
    pod_cap = min(1.0, k / p) * cf
    cp = max(1, int(round(n_tokens * pod_cap)))
    ep_cap = min(1.0, (k / p) / d) * cf
    cd = max(1, int(round(p * cp * ep_cap)))
    ce_target = max(1, int(round(n_tokens * k / per_rank * cf)))
    exp_cap = ce_target / (d * cd)
    return cl.DispatchConfig(num_experts=per_rank * p * d, top_k=k,
                             pod_capacity=pod_cap, ep_capacity=ep_cap,
                             expert_capacity=exp_cap)


def unicast_capacities(dcfg: cl.DispatchConfig, n_tokens: int, k: int,
                       ranks: int, per_rank: int,
                       cf: float) -> cl.DispatchConfig:
    """Rebase a :func:`balanced_capacities` config for the UNICAST
    (per-destination-RANK) packing of ``baseline_dispatch``: fair
    capacity is the balanced per-rank expectation (k/R), and
    ``expert_capacity`` — a fraction of the incoming buffer — must be
    renormalized from the hierarchical D*Cd buffer to the unicast R*Cr
    one, or small decode batches round the expert buffer down to zero
    slots.  Kept next to its hierarchical twin so the two sizing rules
    (which both anticipate the callee's ``max(1, round(...))``) evolve
    together."""
    rank_cap = min(1.0, k / ranks) * cf
    cr = max(1, int(round(n_tokens * rank_cap)))
    ce_target = max(1, int(round(n_tokens * k / per_rank * cf)))
    return dataclasses.replace(dcfg, pod_capacity=rank_cap,
                               expert_capacity=ce_target / (ranks * cr))


def load_balance_loss(logits, ids, num_experts: int):
    """Switch-style aux loss: E * sum_i f_i * P_i (local estimate)."""
    probs = jax.nn.softmax(logits, axis=-1)                  # [N, E]
    onehot = jnp.any(ids[..., None] == jnp.arange(num_experts), axis=1)
    f = jnp.mean(onehot.astype(jnp.float32), axis=0)         # fraction routed
    p_mean = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p_mean)


def moe_ffn(params, x, cfg, pctx: ParallelContext | None,
            capacity_factor: float | None = None):
    """x: [B, S, D] -> ([B, S, D], aux_loss).  params from init_moe."""
    b, s, d = x.shape
    dt = x.dtype
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity
    tokens_in = x.reshape(b * s, d)

    if pctx is None:
        epmesh = cl.EPMesh(pod_axis=None, ep_axis="_none", num_pods=1,
                           ep_per_pod=1)
        dcfg = balanced_capacities(b * s, cfg.top_k, 1, 1, cfg.num_experts,
                                   capacity_factor)
        out, aux = _moe_local(params, tokens_in, cfg, dcfg, epmesh)
        return out.reshape(b, s, d).astype(dt), aux

    use_pod, _ = pctx.ep_ranks(cfg.num_experts)
    p = pctx.num_pods if use_pod else 1
    dd = pctx.data_size
    epmesh = cl.EPMesh(
        pod_axis=pctx.pod_axis if use_pod else None,
        ep_axis=pctx.data_axis, num_pods=p, ep_per_pod=dd)
    per_rank = cfg.num_experts // (p * dd)
    ep_spec = ((pctx.pod_axis, pctx.data_axis) if use_pod
               else (pctx.data_axis,))
    dp_spec = pctx.dp_axes
    n_local = (b * s) // (pctx.num_pods * pctx.data_size)
    dcfg = balanced_capacities(n_local, cfg.top_k, p, dd, per_rank,
                               capacity_factor)
    # The WHOLE round trip — dispatch scheme, return-path scheme and the
    # shared pipeline chunk count G — is one jointly-planned decision:
    # a bound ExecutionPlan resolves it by declared-site lookup; under
    # plan_policy="auto" without a bound plan the same joint sweep runs
    # ad hoc (§5.2 dynamic workflow — decode traces pick the unicast
    # pair at small batch, prefill/train cross to MultiWrite, and the
    # shared-pipeline scorer picks the G where overlapping
    # dispatch/compute/combine chunks beats BOTH halves' per-chunk
    # launch alphas); the declared moe_scheme/moe_combine/moe_microbatch
    # knobs apply under "fixed".
    from repro.core.latency_model import moe_overlap_compute_s
    compute_s = moe_overlap_compute_s(n_local, cfg.top_k, d,
                                      params["w1"].shape[-1],
                                      tp=pctx.model_size)
    pipe_kw = pctx.moe_pipeline_kwargs(
        cfg.num_experts, cfg.top_k, tokens_per_rank=n_local,
        token_bytes=d * x.dtype.itemsize, compute_s=compute_s)
    # the chosen G must divide the local token count; gcd clamps it to
    # the largest divisor <= G (pow-2 grids always divide pow-2 batches).
    # A clamp re-resolves the configuration AT the executed G: the
    # scheme pair is taken from the joint sweep's candidates at the
    # depth the pipeline actually runs, not one it never honors.
    microbatch = math.gcd(max(1, int(pipe_kw["microbatch"])),
                          n_local) or 1
    if microbatch != int(pipe_kw["microbatch"]):
        pipe_kw = pctx.moe_pipeline_kwargs(
            cfg.num_experts, cfg.top_k, tokens_per_rank=n_local,
            token_bytes=d * x.dtype.itemsize, compute_s=compute_s,
            microbatch=microbatch)
    scheme = pipe_kw["moe_scheme"]
    combine_scheme = pipe_kw["moe_combine"]
    if scheme == "baseline":
        dcfg = unicast_capacities(dcfg, n_local, cfg.top_k, p * dd,
                                  per_rank, capacity_factor)

    # deferred TP reduction: the combine tree is LINEAR in the expert
    # outputs, so the row-parallel psum commutes through it — emit partial
    # (F-shard) contributions from the experts and reduce ONCE on the
    # final [N, D] result instead of per-layer [E_l, Ce, D] buffers.
    expert_axis = (None if pctx.moe_deferred_tp_reduce
                   else pctx.model_axis)

    # The chunk pipeline is split at the dispatch/compute boundary:
    # ``dispatch_chunk`` routes + issues the dispatch collectives,
    # ``finish_chunk`` runs the expert FFN and the combine.  The
    # pipelined ``inner`` below issues the NEXT chunk's dispatch before
    # finishing the previous one, so the dispatch collectives of chunk
    # k+1 have no data dependency on the FFN/combine of chunk k and the
    # compiler overlaps them (double buffering: one chunk in flight).

    def dispatch_chunk(tok, router, w1, w3, w2):
        logits = tok.astype(jnp.float32) @ router
        gates, ids = cl.route_topk(logits, cfg.top_k)
        aux = load_balance_loss(logits, ids, cfg.num_experts)
        aux = jax.lax.pmean(aux, dp_spec)
        dispatch_fn = (cl.hierarchical_dispatch if scheme == "hierarchical"
                       else cl.baseline_dispatch)
        exp_tok, exp_gate, st = dispatch_fn(tok, ids, gates, dcfg, epmesh)
        return (exp_tok, exp_gate, st), aux

    def finish_chunk(pack, w1, w3, w2, out_dtype):
        exp_tok, exp_gate, st = pack
        exp_out = _expert_ffn(w1, w3, w2, exp_tok, cfg.act, expert_axis)
        if scheme == "hierarchical":
            combine_fn = (cl.hierarchical_combine
                          if combine_scheme == "hierarchical"
                          else cl.hierarchical_combine_unicast)
        else:
            combine_fn = cl.baseline_combine
        out = combine_fn(exp_out, exp_gate, st)
        if pctx.moe_deferred_tp_reduce:
            out = jax.lax.psum(out, pctx.model_axis)
        return out.astype(out_dtype)

    def one_chunk(tok, router, w1, w3, w2):
        pack, aux = dispatch_chunk(tok, router, w1, w3, w2)
        return finish_chunk(pack, w1, w3, w2, tok.dtype), aux

    def inner(tok, router, w1, w3, w2):
        g = microbatch
        if g <= 1:
            return one_chunk(tok, router, w1, w3, w2)
        n_loc, h = tok.shape
        assert n_loc % g == 0, (n_loc, g)
        chunks = tok.reshape(g, n_loc // g, h)
        # software-pipelined chunk loop (double-buffered): the scan body
        # dispatches chunk k+1 FIRST, then finishes chunk k — per-chunk
        # results are identical to the serial loop (bit-exact), only the
        # issue order changes, which is what lets the dispatch traffic
        # hide behind the previous chunk's expert FFN + combine.
        pack0, aux0 = dispatch_chunk(chunks[0], router, w1, w3, w2)

        def body(carry, tok_next):
            pack_next, aux_next = dispatch_chunk(tok_next, router,
                                                 w1, w3, w2)
            out_prev = finish_chunk(carry, w1, w3, w2, tok.dtype)
            return pack_next, (out_prev, aux_next)

        pack_last, (outs, auxs) = jax.lax.scan(body, pack0, chunks[1:])
        out_last = finish_chunk(pack_last, w1, w3, w2, tok.dtype)
        out = jnp.concatenate([outs.reshape(n_loc - n_loc // g, h),
                               out_last], axis=0)
        return out, (aux0 + jnp.sum(auxs)) / g

    out, aux = shard_map(
        inner, mesh=pctx.mesh,
        in_specs=(P(dp_spec, None),            # tokens split over DP ranks
                  P(None, None),               # router replicated
                  P(ep_spec, None, pctx.model_axis),
                  P(ep_spec, None, pctx.model_axis),
                  P(ep_spec, pctx.model_axis, None)),
        out_specs=(P(dp_spec, None), P()),
        check_vma=False,
    )(tokens_in, params["router"].astype(jnp.float32),
      params["w1"], params["w3"], params["w2"])
    return out.reshape(b, s, d).astype(dt), aux


def _moe_local(params, tokens, cfg, dcfg, epmesh):
    """Single-device path (smoke tests): same dispatch code, no axes."""
    logits = tokens.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    gates, ids = cl.route_topk(logits, cfg.top_k)
    aux = load_balance_loss(logits, ids, cfg.num_experts)
    exp_tok, exp_gate, st = cl.hierarchical_dispatch(
        tokens, ids, gates, dcfg, epmesh)
    exp_out = _expert_ffn(params["w1"], params["w3"], params["w2"],
                          exp_tok, cfg.act, None)
    return cl.hierarchical_combine(exp_out, exp_gate, st), aux
