"""RWKV-6 ("Finch") blocks: time-mix (WKV6) + channel-mix.

Attention-free: per-head matrix-valued state [dk, dv] with data-dependent
per-channel decay (the Finch headline — a rank-``rwkv_decay_lora`` LoRA
produces log-decays from the shifted input).  Token-shift mixing uses
static per-channel coefficients (the released model also LoRAs the mix
coefficients; simplified — noted in DESIGN.md).

Decode state per layer: two shift registers [B, D] + WKV state
[B, H, dk, dv] — O(1)/token, so this arch runs the long_500k cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models import layers as L


def _dims(cfg: ModelConfig):
    dk = cfg.rwkv_head_dim
    heads = cfg.d_model // dk
    return heads, dk


def init_rwkv_block(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    heads, dk = _dims(cfg)
    lora = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    sc = 1.0 / math.sqrt(d)
    return {
        "ln1": L.init_rmsnorm(d),
        "ln2": L.init_rmsnorm(d),
        "mu": L.truncated_normal(ks[0], (5, d), 0.3),   # r,k,v,w,g mixes
        "wr": L.truncated_normal(ks[1], (d, d), sc),
        "wk": L.truncated_normal(ks[2], (d, d), sc),
        "wv": L.truncated_normal(ks[3], (d, d), sc),
        "wg": L.truncated_normal(ks[4], (d, d), sc),
        "w0": jnp.zeros((d,), jnp.float32),             # base log-log decay
        "wA": L.truncated_normal(ks[5], (d, lora), sc),
        "wB": L.truncated_normal(ks[6], (lora, d), 1.0 / math.sqrt(lora)),
        "u": L.truncated_normal(ks[7], (heads, dk), 0.3),
        "gn": L.init_rmsnorm(d),                        # post-wkv group norm
        "wo": L.truncated_normal(ks[8], (d, d), sc),
        # channel mix
        "cmu": L.truncated_normal(ks[9], (2, d), 0.3),  # k, r mixes
        "ck": L.truncated_normal(ks[10], (d, f), sc),
        "cr": L.truncated_normal(ks[11], (d, d), sc),
        "cv": L.truncated_normal(jax.random.fold_in(key, 99), (f, d),
                                 1.0 / math.sqrt(f)),
    }


def _shift_train(x):
    """xx[t] = x[t-1], zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _mix(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _decay_logw(p, xw):
    """Data-dependent per-channel log decay (<= ~0)."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    return -jnp.exp(p["w0"] + lo)                      # [.., d]


def time_mix(p, x, cfg, state=None, *, use_pallas=False):
    """x: [B, S, D] (train/prefill) or with state for decode handled in
    time_mix_decode.  Returns y [B, S, D] (+ final wkv state if asked)."""
    b, s, d = x.shape
    heads, dk = _dims(cfg)
    dt = x.dtype
    xx = _shift_train(x)
    xr, xk, xv, xw, xg = (_mix(x, xx, p["mu"][i]) for i in range(5))
    r = xr @ p["wr"].astype(dt)
    k = xk @ p["wk"].astype(dt)
    v = xv @ p["wv"].astype(dt)
    g = xg @ p["wg"].astype(dt)
    logw = _decay_logw(p, xw)                          # [B, S, D] f32

    def to_heads(t):
        return t.reshape(b, s, heads, dk).transpose(0, 2, 1, 3).reshape(
            b * heads, s, dk)

    rh, kh, vh, wh = to_heads(r), to_heads(k), to_heads(v), \
        to_heads(logw.astype(jnp.float32))
    u = jnp.tile(p["u"], (b, 1))                       # [B*H, dk]
    if state is None:
        y = ops.rwkv6_scan(rh, kh, vh, wh, u, use_pallas=use_pallas)
        final = None
    else:
        y, final = ref.rwkv6_chunked_jnp(rh, kh, vh, wh, u, s0=state,
                                         return_final=True)
    y = y.reshape(b, heads, s, dk).transpose(0, 2, 1, 3).reshape(b, s, d)
    y = L.rmsnorm(p["gn"], y, cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ p["wo"].astype(dt)
    return out, x[:, -1], final


def time_mix_decode(p, x, shift, wkv, cfg):
    """One token.  x: [B, 1, D]; shift: [B, D]; wkv: [B, H, dk, dv]."""
    b, _, d = x.shape
    heads, dk = _dims(cfg)
    dt = x.dtype
    xx = shift[:, None].astype(dt)
    xr, xk, xv, xw, xg = (_mix(x, xx, p["mu"][i]) for i in range(5))
    r = (xr @ p["wr"].astype(dt))[:, 0]
    k = (xk @ p["wk"].astype(dt))[:, 0]
    v = (xv @ p["wv"].astype(dt))[:, 0]
    g = (xg @ p["wg"].astype(dt))[:, 0]
    logw = _decay_logw(p, xw)[:, 0]                    # [B, D]

    def to_heads(t):
        return t.reshape(b * heads, dk)

    S = wkv.reshape(b * heads, dk, dk)
    u = jnp.tile(p["u"], (b, 1))
    S, y = ref.rwkv6_decode_step(
        S, to_heads(r.astype(jnp.float32)), to_heads(k.astype(jnp.float32)),
        to_heads(v.astype(jnp.float32)),
        to_heads(logw), u)
    y = y.reshape(b, 1, d).astype(dt)
    y = L.rmsnorm(p["gn"], y, cfg.norm_eps)
    out = (y * jax.nn.silu(g[:, None])) @ p["wo"].astype(dt)
    return out, x[:, -1], S.reshape(b, heads, dk, dk)


def channel_mix(p, x, shift=None):
    """x: [B, S, D].  shift: [B, D] decode shift register or None."""
    dt = x.dtype
    xx = _shift_train(x) if shift is None else shift[:, None].astype(dt)
    xk = _mix(x, xx, p["cmu"][0])
    xr = _mix(x, xx, p["cmu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(dt)))
    return jax.nn.sigmoid(xr @ p["cr"].astype(dt)) * (
        k @ p["cv"].astype(dt)), x[:, -1]


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def init_rwkv6(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.init_embedding(ks[1], cfg.vocab, cfg.d_model),
        "ln_in": L.init_rmsnorm(cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "layers": jax.vmap(lambda k: init_rwkv_block(k, cfg))(layer_keys),
        "unembed": {"w": L.truncated_normal(
            ks[2], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5)},
    }


def rwkv6_hidden(params, cfg, pctx, x, *, use_pallas=False):
    x = L.rmsnorm(params["ln_in"], x, cfg.norm_eps)

    def body(carry, lp):
        def inner(lp_, x_):
            t, _, _ = time_mix(lp_, L.rmsnorm(lp_["ln1"], x_, cfg.norm_eps),
                               cfg, use_pallas=use_pallas)
            x_ = x_ + t
            c, _ = channel_mix(lp_, L.rmsnorm(lp_["ln2"], x_, cfg.norm_eps))
            from repro.parallel.context import shard_residual
            return shard_residual(x_ + c, pctx)

        from repro.models.transformer import _remat
        return _remat(inner, pctx)(lp, carry), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), \
        jnp.zeros((), jnp.float32)


def rwkv6_init_state(cfg, batch, dtype=jnp.bfloat16):
    heads, dk = _dims(cfg)
    n = cfg.n_layers
    return {
        "tshift": jnp.zeros((n, batch, cfg.d_model), dtype),
        "cshift": jnp.zeros((n, batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((n, batch, heads, dk, dk), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def rwkv6_prefill(params, cfg, pctx, x, state):
    """Prefill: chunked scan per layer, capturing final states."""
    x = L.rmsnorm(params["ln_in"], x, cfg.norm_eps)
    b = x.shape[0]
    heads, dk = _dims(cfg)

    def body(x, lp):
        s0 = jnp.zeros((b * heads, dk, dk), jnp.float32)
        t, tsh, wkv = time_mix(lp, L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                               cfg, state=s0)
        x = x + t
        c, csh = channel_mix(lp, L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        x = x + c
        return x, (tsh, csh, wkv.reshape(b, heads, dk, dk))

    x, (tsh, csh, wkv) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_state = {"tshift": tsh.astype(state["tshift"].dtype),
                 "cshift": csh.astype(state["cshift"].dtype),
                 "wkv": wkv,
                 "len": jnp.asarray(x.shape[1], jnp.int32)}
    return x, new_state


def rwkv6_decode_step(params, cfg, pctx, x, state):
    x = L.rmsnorm(params["ln_in"], x, cfg.norm_eps)

    def body(x, xs):
        lp, tsh, csh, wkv = xs
        t, tsh2, wkv2 = time_mix_decode(
            lp, L.rmsnorm(lp["ln1"], x, cfg.norm_eps), tsh, wkv, cfg)
        x = x + t
        c, csh2 = channel_mix(lp, L.rmsnorm(lp["ln2"], x, cfg.norm_eps),
                              csh)
        x = x + c
        return x, (tsh2.astype(tsh.dtype), csh2.astype(csh.dtype), wkv2)

    x, (tsh, csh, wkv) = jax.lax.scan(
        body, x, (params["layers"], state["tshift"], state["cshift"],
                  state["wkv"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"tshift": tsh, "cshift": csh, "wkv": wkv,
               "len": state["len"] + 1}
