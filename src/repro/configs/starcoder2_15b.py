"""StarCoder2-15B [arXiv:2402.19173; hf:bigcode/starcoder2-15b].

Dense decoder, GQA (4 kv heads), RoPE, non-gated GELU MLP (4x),
learned-bias-free; vocab 49152 (GQA, RoPE per the assignment table).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152,
    mlp_gated=False, act="gelu", rope_theta=1e5,
    tie_embeddings=False,
    source="arXiv:2402.19173; hf",
)
