"""Gemma2-9B [arXiv:2408.00118; hf:google/gemma-2-9b].

Dense decoder, GQA kv=8, head_dim 256, alternating local (4096-window)
/ global attention, attn logit softcap 50, final logit softcap 30,
post-block RMSNorm, gated GELU MLP, 256k vocab, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2_9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab=256000,
    mlp_gated=True, act="gelu",
    window=4096, local_global_alternating=True,
    attn_softcap=50.0, final_softcap=30.0, post_norm=True,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
