"""Model configuration schema + registry for the assigned architectures.

One ``<arch>.py`` per assigned architecture defines ``CONFIG`` with the
exact published hyperparameters; ``get_config(name)`` loads it.  Reduced
("smoke") variants for CPU tests come from :func:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | rwkv | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None    # default d_model // n_heads
    # --- attention ---------------------------------------------------------
    rope_theta: float = 1e4
    window: Optional[int] = None            # sliding-window size
    local_global_alternating: bool = False  # gemma2: odd layers global
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    mrope_sections: Optional[tuple] = None  # qwen2-vl M-RoPE (t, h, w)
    post_norm: bool = False                 # gemma2 post-block RMSNorm
    # --- MLP ----------------------------------------------------------------
    mlp_gated: bool = True
    act: str = "silu"               # silu | gelu | relu2
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None  # expert hidden dim (defaults to d_ff)
    n_shared_experts: int = 0       # DeepSeek-style always-on experts
    first_k_dense: int = 0          # leading dense layers in an MoE stack
    moe_capacity: float = 1.25      # capacity factor vs balanced routing
    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0      # zamba2: shared attn block period
    # --- rwkv ----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    # --- enc-dec --------------------------------------------------------------
    n_enc_layers: int = 0
    # --- frontend -------------------------------------------------------------
    input_mode: str = "tokens"      # tokens | embeddings (stub frontends)
    # --- misc ------------------------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    source: str = ""                # provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def reduced(self, *, n_layers=2, d_model=64, n_heads=4, n_kv_heads=None,
                d_ff=128, vocab=512, num_experts=None, ssm_state=16,
                **kw) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv_heads if n_kv_heads is not None
            else max(1, min(self.n_kv_heads, n_heads // 2)),
            d_ff=d_ff, vocab=vocab, d_head=None,
        )
        if self.is_moe:
            changes["num_experts"] = (num_experts if num_experts
                                      else min(self.num_experts, 8))
            changes["top_k"] = min(self.top_k, 2)
            changes["moe_d_ff"] = d_ff
            changes["first_k_dense"] = min(self.first_k_dense, 1)
            changes["moe_capacity"] = 8.0   # no capacity drops at smoke N
        if self.family == "hybrid":
            changes["ssm_state"] = ssm_state
            changes["ssm_head_dim"] = 16
            changes["shared_attn_every"] = 2
            changes["n_layers"] = max(n_layers, 4)
        if self.family == "rwkv":
            changes["rwkv_head_dim"] = 16
            changes["rwkv_decay_lora"] = 8
        if self.family == "encdec":
            changes["n_enc_layers"] = n_layers
        if self.window:
            changes["window"] = 32
        if self.mrope_sections:
            # sections sum to head_dim // 2
            hd = d_model // n_heads
            changes["mrope_sections"] = (hd // 2 - 2 * (hd // 8),
                                         hd // 8, hd // 8)
        changes.update(kw)
        return dataclasses.replace(self, **changes)


ARCH_IDS = [
    "starcoder2_15b", "minitron_8b", "mistral_nemo_12b", "gemma2_9b",
    "dbrx_132b", "kimi_k2_1t", "qwen2_vl_2b", "seamless_m4t_medium",
    "zamba2_7b", "rwkv6_7b",
]

# canonical dash-style aliases from the assignment table
ALIASES = {
    "starcoder2-15b": "starcoder2_15b",
    "minitron-8b": "minitron_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma2-9b": "gemma2_9b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "kimi-k2-1t": "kimi_k2_1t",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-7b": "rwkv6_7b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Shapes from the assignment (per-arch shape sets)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs a sub-quadratic decode path: run only for SSM/hybrid.
LONG_CONTEXT_ARCHS = {"zamba2_7b", "rwkv6_7b"}


def shapes_for(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if ALIASES.get(arch, arch) in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out


def cell_is_skipped(arch: str, shape: str) -> str | None:
    """Returns a skip reason, or None if the (arch, shape) cell runs."""
    if shape == "long_500k" and ALIASES.get(arch, arch) not in LONG_CONTEXT_ARCHS:
        return ("full-attention arch: 524k dense-KV decode is "
                "quadratic-history; no sub-quadratic path in published form")
    return None
