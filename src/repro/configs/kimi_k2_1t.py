"""Kimi-K2 1T-A32B [arXiv:2501.kimi2 paper table; unverified tier].

Trillion-parameter MoE (DeepSeek-V3-family): 61 layers, d_model 7168,
384 experts top-8 with expert d_ff 2048, 1 shared expert, first layer
dense, GQA kv=8 per the assignment table (the released model uses MLA;
the table pins GQA — noted in DESIGN.md §Arch-applicability), vocab
163840.  The flagship MultiWrite cell: EP spans pods.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi_k2_1t", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432,               # dense-layer FFN (DeepSeek-V3 family value)
    vocab=163840,
    num_experts=384, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, first_k_dense=1,
    mlp_gated=True, act="silu", rope_theta=5e4,
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (paper table); unverified",
)
