"""Qwen2-VL-2B [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B].

VLM: the assignment covers the transformer BACKBONE only; the vision
frontend is a stub (input_specs supplies precomputed patch embeddings +
3-D M-RoPE position ids).  28 layers, d_model 1536, GQA kv=2, M-RoPE
sections (t,h,w) = (16, 24, 24) over head_dim 128, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    mlp_gated=True, act="silu",
    input_mode="embeddings",
    tie_embeddings=True,
    source="arXiv:2409.12191; hf",
)
