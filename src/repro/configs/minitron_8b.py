"""Minitron-8B (pruned Nemotron-4) [arXiv:2407.14679; hf:nvidia/Minitron-8B-Base].

Dense decoder, GQA kv=8, squared-ReLU non-gated MLP (Nemotron family),
256k vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron_8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000,
    mlp_gated=False, act="relu2", rope_theta=1e4,
    tie_embeddings=False,
    source="arXiv:2407.14679; hf",
)
