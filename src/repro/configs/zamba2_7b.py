"""Zamba2-7B [arXiv:2411.15242; hf:Zyphra/Zamba2-7B; unverified tier].

Hybrid: 81 Mamba2 blocks with a SHARED attention+MLP block invoked every
6 layers (Zamba2's weight-shared global block; the released model
alternates two shared blocks + per-invocation LoRA — simplified to one
shared block, noted in DESIGN.md).  d_model 3584, ssm_state 64, mamba2
head_dim 64, expand 2; shared attn 32H kv=32 (MHA), d_ff 14336.
Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    shared_attn_every=6,
    mlp_gated=True, act="silu",
    tie_embeddings=True,
    source="arXiv:2411.15242; unverified",
)
