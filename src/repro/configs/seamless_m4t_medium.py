"""SeamlessM4T-medium [arXiv:2308.11596; hf:facebook/seamless-m4t-medium].

Encoder-decoder audio backbone: 12 encoder + 12 decoder layers,
d_model 1024, MHA (kv=16 == heads), non-gated GELU FFN 4096, vocab
256206.  The speech frontend is a stub: input_specs supplies precomputed
frame embeddings to the encoder; the decoder consumes tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    mlp_gated=False, act="gelu",
    input_mode="embeddings",
    tie_embeddings=True,
    source="arXiv:2308.11596; hf",
)
