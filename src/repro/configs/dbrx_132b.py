"""DBRX-132B [hf:databricks/dbrx-base; unverified tier].

Fine-grained MoE decoder: 16 experts, top-4, expert d_ff 10752,
GQA kv=8, vocab 100352, rope_theta 5e5.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx_132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    num_experts=16, top_k=4, moe_d_ff=10752,
    mlp_gated=True, act="silu", rope_theta=5e5,
    tie_embeddings=False,
    source="hf:databricks/dbrx-base; unverified",
)
