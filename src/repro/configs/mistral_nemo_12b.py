"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder, GQA kv=8, explicit head_dim=128 (d_model 5120 / 32 heads
would give 160; the released model uses 128), gated SiLU MLP, 128k ctx
(rope_theta 1e6), vocab 131072 (Tekken).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral_nemo_12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072,
    mlp_gated=True, act="silu", rope_theta=1e6,
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
