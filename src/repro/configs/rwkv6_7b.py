"""RWKV6-7B "Finch" [arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b].

Attention-free: 32 layers of time-mix (WKV6 with data-dependent decay
via a rank-64 LoRA) + channel-mix (squared-ReLU), d_model 4096, wkv head
dim 64 (=> 64 heads), d_ff 14336, vocab 65536.  Sub-quadratic: runs the
long_500k cell.  The paper's technique applies only to this arch's DP/TP
collectives (attention-free; no MoE dispatch) — see DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536,
    rwkv_head_dim=64, rwkv_decay_lora=64,
    tie_embeddings=False,
    source="arXiv:2404.05892; hf",
)
