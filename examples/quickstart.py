"""Quickstart: MultiWrite in 60 seconds.

1. The semantic: one MultiWrite == one copy per bottleneck link.
2. The paper's AllGather schedules + latency model.
3. A shard_map MultiWrite AllGather on whatever devices you have.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import latency_model as lm
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import split_tp_full_mesh, two_server_cluster

# --- 1. the semantic ---------------------------------------------------------
print("== MultiWrite semantic ==")
topo = two_server_cluster()          # 2 servers x 8 NPUs, rail-optimized
sim = MultiWriteSimulator(topo)
token = np.arange(7168, dtype=np.uint8)

# unicast: 4 copies of the token cross NPU0's rail
for dst in (9, 10, 12, 15):
    sim.write(0, dst, "tok", token)
print(f"unicast   rail bytes: {sim.link_bytes[(0, 8)]:8d} "
      f"(redundant: {sim.redundant_bytes()[(0, 8)]})")

sim2 = MultiWriteSimulator(topo)
sim2.multiwrite(0, {d: "tok" for d in (9, 10, 12, 15)}, token)
print(f"multiwrite rail bytes: {sim2.link_bytes[(0, 8)]:8d} "
      f"(relay replicates at NPU8)")

# --- 2. the paper's AllGather schedules -------------------------------------
print("\n== AllGather on the split-TP full mesh (16 MB/rank) ==")
for scheme in ("baseline", "unicast_paired", "multiwrite_paired"):
    t = lm.allgather_latency(scheme, 16 * 2**20)
    print(f"  {scheme:20s}: {t*1e6:7.1f} us")
print(f"  -> MultiWrite cuts latency "
      f"{100 * (1 - lm.allgather_latency('multiwrite_paired', 16*2**20) / lm.allgather_latency('baseline', 16*2**20)):.0f}%"
      f"  (paper Fig 6: ~30%)")

# correctness: run the schedule through the packet simulator
topo8, domains = split_tp_full_mesh(8, tp=4)
sim3 = MultiWriteSimulator(topo8)
payloads = [np.random.default_rng(i).integers(0, 256, 4096, dtype=np.uint8)
            for i in range(8)]
sch.ALLGATHER_SCHEMES["multiwrite_paired"](sim3, domains, payloads)
sch.check_allgather(sim3, domains, payloads)
print("  schedule delivers every fragment bit-exactly: OK")

# --- 2b. the planner: scheme choice is dynamic (§5.2) ------------------------
print("\n== planner: baseline below the Fig 7 crossover, MultiWrite above ==")
from repro.core import planner as pl  # noqa: E402

for frag in (256 * 2**10, 16 * 2**20):
    d = pl.default_planner().choose("allgather", frag, topo8)
    print(f"  {frag/2**20:6.2f} MB -> {d.plan} "
          f"(predicted {d.predicted_s*1e6:.0f} us, "
          f"{d.speedup_pct:+.0f}% vs baseline)")

# --- 3. the JAX collective ----------------------------------------------------
print("\n== shard_map MultiWrite AllGather (local devices) ==")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import functools  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from repro.core import collectives as cl  # noqa: E402
from repro.parallel.compat import shard_map  # noqa: E402

n = len(jax.devices())
if n >= 2 and n % 2 == 0:
    mesh = jax.make_mesh((n,), ("x",))
    x = jnp.arange(n * 8.0).reshape(n * 4, 2)
    fn = jax.jit(shard_map(
        functools.partial(cl.multiwrite_allgather, axis_name="x",
                          split=0.5),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    ref = jax.jit(shard_map(
        functools.partial(cl.allgather_reference, axis_name="x"),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    same = bool(jnp.array_equal(fn(x), ref(x)))
    print(f"  {n} devices: multiwrite_allgather == reference: {same}")
else:
    print(f"  ({n} device(s) — run tests/multidev for the 8-device check)")
print("\nDone.  See examples/train_100m.py for the end-to-end driver.")
