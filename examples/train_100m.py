"""End-to-end driver: train a ~100M-parameter MoE LM for a few hundred
steps on the synthetic pipeline, with checkpoint/restart fault tolerance.

This exercises every substrate at once: model (MoE family — the paper's
dispatch path in its single-device degenerate form), data pipeline,
optimizer, FT trainer, checkpointing, straggler ledger.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM, batch_for_model
from repro.models.api import build_model, param_count
from repro.optim import adamw, cosine_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    # ~100M params: 8 layers, d=512, MoE 8e top-2 (kimi-family shrunk)
    cfg = ModelConfig(
        name="moe_100m", family="moe",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab=32000,
        num_experts=8, top_k=2, moe_d_ff=1024, n_shared_experts=1,
        first_k_dense=1, moe_capacity=2.0,
        mlp_gated=True, act="silu", tie_embeddings=True,
    )
    model = build_model(cfg, dtype=jnp.float32)
    n_params = param_count(model.init(jax.random.key(0)))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=7))
    opt = adamw(lr=cosine_schedule(3e-4, warmup=20, total=args.steps),
                weight_decay=0.01)
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=args.ckpt_dir, log_every=20)

    stragglers = []
    trainer = Trainer(
        model, opt, lambda s: batch_for_model(cfg, data.batch(s)), tcfg,
        init_rng=jax.random.key(0),
        straggler_hook=lambda s, dt: stragglers.append((s, dt)))
    print(f"starting at step {int(trainer.state.step)} "
          f"(resume={'yes' if int(trainer.state.step) else 'no'})")
    t0 = time.monotonic()
    hist = trainer.run()
    wall = time.monotonic() - t0

    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    toks = args.batch * args.seq * len(hist)
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({wall:.0f}s, {toks/max(wall,1e-9):.0f} tok/s on CPU)")
    print(f"stragglers flagged: {len(stragglers)}; "
          f"checkpoints in {args.ckpt_dir}")
    assert last < first, "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
