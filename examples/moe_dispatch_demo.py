"""MoE dispatch demo: the paper's Table-1 scenario end-to-end in JAX.

Spawns 8 CPU devices (2 "pods" x 4 "chips"), routes tokens top-2 over 16
experts, and runs BOTH dispatch schemes:

  baseline    one copy per (token, destination chip) crosses the pod axis
  multiwrite  ONE copy per (token, destination pod), relay replication

then compares (a) numerical equality of the MoE layer output, and (b) the
pod-axis all-to-all bytes parsed from each scheme's compiled HLO — the
dry-run version of the paper's Table 1.

Run:  PYTHONPATH=src python examples/moe_dispatch_demo.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import collectives as cl
from repro.parallel.compat import shard_map  # noqa: E402
from repro.launch.hlo_analysis import MeshLayout  # noqa: E402
from repro.launch.hlo_module import analyze_module  # noqa: E402

PODS, EP = 2, 4
EXPERTS, TOPK, N_PER, H = 16, 2, 64, 32


def build(scheme, mesh):
    epmesh = cl.EPMesh("pod", "ep", PODS, EP)
    cfg = cl.DispatchConfig(EXPERTS, TOPK, 1.0, 1.0, 1.0)
    per_rank = EXPERTS // (PODS * EP)

    def step(tok, ids, gates):
        scale = (jnp.arange(EXPERTS, dtype=jnp.float32) + 1.0) * 0.05
        rank = jax.lax.axis_index("pod") * EP + jax.lax.axis_index("ep")
        local = scale[rank * per_rank + jnp.arange(per_rank)][:, None, None]
        if scheme == "multiwrite":
            et, eg, st = cl.hierarchical_dispatch(tok, ids, gates, cfg,
                                                  epmesh)
            return cl.hierarchical_combine(et * local, eg, st)
        et, eg, st = cl.baseline_dispatch(tok, ids, gates, cfg, epmesh)
        return cl.baseline_combine(et * local, eg, st)

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P(("pod", "ep")),) * 3,
        out_specs=P(("pod", "ep")), check_vma=False))


def main():
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((PODS, EP), ("pod", "ep"))
    rng = np.random.default_rng(0)
    n = N_PER * PODS * EP
    tokens = jnp.asarray(rng.normal(size=(n, H)).astype(np.float32))
    logits = jnp.asarray(rng.normal(size=(n, EXPERTS)).astype(np.float32))
    gates, ids = cl.route_topk(logits, TOPK)

    outs, pod_bytes = {}, {}
    layout = MeshLayout(("pod", "ep"), (PODS, EP))
    for scheme in ("baseline", "multiwrite"):
        fn = build(scheme, mesh)
        lowered = fn.lower(tokens, ids, gates)
        cost = analyze_module(lowered.compile().as_text(), layout,
                              default_axis="ep")
        pod_bytes[scheme] = cost.collective_by_axis.get("pod", 0)
        outs[scheme] = np.asarray(fn(tokens, ids, gates))

    err = np.max(np.abs(outs["baseline"] - outs["multiwrite"]))
    print(f"outputs identical across schemes: max|diff| = {err:.2e}")
    b, m = pod_bytes["baseline"], pod_bytes["multiwrite"]
    print(f"pod-axis (slow link) wire bytes per chip:")
    print(f"  baseline (unicast): {b:10.0f}")
    print(f"  multiwrite        : {m:10.0f}")
    print(f"  reduction         : {100 * (1 - m / b):.0f}%  "
          f"(paper Table 1: one crossing per pod vs per expert)")
    assert m < b
    print("OK")


if __name__ == "__main__":
    main()
