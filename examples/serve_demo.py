"""Serving demo: batched generation with prefill + KV-cache decode.

Trains nothing — loads random weights into a small dense model and a
small RWKV6 (attention-free) model, generates with the ServeEngine, and
reports prefill/decode timings and tokens/s on this host.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.runtime.server import ServeConfig, ServeEngine


def demo(arch: str, max_new: int = 16):
    cfg = get_config(arch).reduced(n_layers=4, d_model=128, n_heads=4,
                                   d_ff=256, vocab=1024)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=max_new,
                                     temperature=0.0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(4, 32)).astype(np.int32)
    out = engine.generate(prompts)
    dec_s = engine.stats["decode_s"]
    print(f"{arch:24s} generated {out.shape} "
          f"prefill={engine.stats['prefill_s']*1e3:.0f}ms "
          f"decode={dec_s*1e3:.0f}ms "
          f"({out.size / max(dec_s, 1e-9):.0f} tok/s decode)")
    # determinism check
    out2 = ServeEngine(model, params,
                       ServeConfig(max_new_tokens=max_new)).generate(prompts)
    assert (out == out2).all()
    return out


def demo_continuous(arch: str = "rwkv6_7b", max_new: int = 12):
    """Continuous batching against the live engine: requests arrive
    staggered, join as cohorts between decode steps while earlier
    cohorts are still decoding, and finished sequences exit without a
    drain barrier — bit-exact with the one-shot batched generate
    (cohort rows are numerically independent under greedy decoding)."""
    from repro.serving import (AdmissionController, BatchScheduler,
                               Request, RequestQueue)

    cfg = get_config(arch).reduced(n_layers=4, d_model=128, n_heads=4,
                                   d_ff=256, vocab=1024)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=max_new,
                                     temperature=0.0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(6, 32)).astype(np.int32)
    ref = engine.generate(prompts)          # one-shot: one cohort at t=0

    queue = RequestQueue()
    for i in range(prompts.shape[0]):
        queue.push(Request(rid=i, arrival_s=0.003 * i,
                           prompt=prompts[i], max_new=max_new))
    sched = BatchScheduler(
        queue=queue,
        # capacity 3 forces several cohorts: later requests join while
        # earlier cohorts still hold decode slots
        admission=AdmissionController(capacity=3, policy="greedy"),
        engine=engine, eos_id=engine.cfg.eos_id, seed=0)
    sched.run_until_drained()
    out = np.zeros_like(ref)
    for req in sched.completed:
        toks = req.tokens[:max_new]
        out[req.rid, :len(toks)] = toks
    assert (out == ref).all(), "continuous batching diverged from one-shot"
    rep = sched.report()
    print(f"{arch:24s} continuous: {rep['completed']} request(s), "
          f"{rep['iterations']} iteration(s), max in-flight "
          f"{rep['max_in_flight']} (capacity 3), TTFT p99 "
          f"{rep['ttft_p99_s'] * 1e3:.1f}ms — bit-exact vs one-shot")


def main():
    for arch in ("mistral_nemo_12b", "gemma2_9b", "rwkv6_7b", "zamba2_7b"):
        demo(arch)
    demo_continuous()
    print("OK — all families serve deterministically; continuous "
          "batching is bit-exact with one-shot generate.")


if __name__ == "__main__":
    main()
