"""Benchmark harness entry point: one function per paper table/figure,
plus micro-benchmarks of this repo's own layers.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig6_allgather

Prints ``name,metric,value`` CSV at the end; paper reproductions print
human tables as they go.  The dry-run roofline table is produced by
``benchmarks.roofline`` (reads results/dryrun/*.json).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def bench_kernels():
    """Micro-bench the Pallas kernels (interpret mode — CORRECTNESS path
    timing only; TPU perf comes from the dry-run roofline)."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 256, 64)), jnp.float32)
    t0 = time.monotonic()
    ops.flash_attention(q, q, q, use_pallas=True, block_q=128,
                        block_k=128).block_until_ready()
    rows.append({"name": "flash_attention_interp_256", "metric": "s",
                 "value": time.monotonic() - t0})
    t0 = time.monotonic()
    ref.attention_ref(q, q, q).block_until_ready()
    rows.append({"name": "attention_ref_256", "metric": "s",
                 "value": time.monotonic() - t0})
    return rows


def bench_dispatch_sim():
    """Simulator throughput on the Table-1 workload."""
    from repro.core import latency_model as lm
    from repro.core import schedules as sch
    from repro.core.multiwrite import MultiWriteSimulator
    from repro.core.topology import two_server_cluster
    rows = []
    for batch in (64, 1024):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        routing = sch.make_routing(batch, 16, 64, 8, seed=1)
        t0 = time.monotonic()
        sch.dispatch_multiwrite(sim, routing, lm.TOKEN_BYTES)
        rows.append({"name": f"sim_dispatch_mw_b{batch}", "metric": "s",
                     "value": time.monotonic() - t0})
    return rows


def bench_train_throughput():
    """Tiny-model CPU train-step wall time (framework overhead check)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM, batch_for_model
    from repro.models.api import build_model
    from repro.optim import adamw
    from repro.runtime.trainer import TrainState, make_train_step
    cfg = get_config("mistral_nemo_12b").reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=256)
    model = build_model(cfg, dtype=jnp.float32)
    opt = adamw(lr=1e-3)
    params = model.init(jax.random.key(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    data = SyntheticLM(DataConfig(vocab=256, seq_len=64, global_batch=8))
    step = make_train_step(model, opt, donate=False)
    batch = batch_for_model(cfg, data.batch(0))
    state, _ = step(state, batch)                     # compile
    t0 = time.monotonic()
    m = None
    for i in range(5):
        state, m = step(state, batch_for_model(cfg, data.batch(i + 1)))
    jax.block_until_ready(m)
    return [{"name": "train_step_smoke_cpu", "metric": "s/step",
             "value": (time.monotonic() - t0) / 5}]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import paper_figures
    csv_rows = []
    for name, fn in paper_figures.ALL.items():
        if args.only and args.only != name:
            continue
        rows = fn()
        for r in rows:
            tag = r.get('scheme', r.get('batch', r.get('msg_mb', '')))
            for k, v in r.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    csv_rows.append((f"{name}.{tag}", k, v))
    if args.only is None:
        for bench in (bench_kernels, bench_dispatch_sim,
                      bench_train_throughput):
            for r in bench():
                csv_rows.append((r["name"], r["metric"], r["value"]))

    print("\nname,metric,value")
    for name, metric, value in csv_rows:
        print(f"{name},{metric},{value}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
