"""Benchmark harness entry point: one function per paper table/figure,
plus micro-benchmarks of this repo's own layers.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig6_allgather

Prints ``name,metric,value`` CSV at the end; paper reproductions print
human tables as they go.  The dry-run roofline table is produced by
``benchmarks.roofline`` (reads results/dryrun/*.json).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def run_metadata(fabric=None):
    """Provenance stamped into every BENCH_*.json: commit, time, fabric,
    JAX version and telemetry schema — without it the perf trajectory
    across PRs is not attributable to anything."""
    import os
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    try:
        import jax
        jax_version = jax.__version__
    except Exception:
        jax_version = None
    from repro.telemetry.store import SCHEMA_VERSION
    return {"git_sha": sha, "ts": time.time(), "fabric": fabric,
            "jax_version": jax_version, "schema_version": SCHEMA_VERSION}


def bench_kernels():
    """Micro-bench the Pallas kernels (interpret mode — CORRECTNESS path
    timing only; TPU perf comes from the dry-run roofline)."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 256, 64)), jnp.float32)
    t0 = time.monotonic()
    ops.flash_attention(q, q, q, use_pallas=True, block_q=128,
                        block_k=128).block_until_ready()
    rows.append({"name": "flash_attention_interp_256", "metric": "s",
                 "value": time.monotonic() - t0})
    t0 = time.monotonic()
    ref.attention_ref(q, q, q).block_until_ready()
    rows.append({"name": "attention_ref_256", "metric": "s",
                 "value": time.monotonic() - t0})
    return rows


def bench_dispatch_sim():
    """Simulator throughput on the Table-1 workload."""
    from repro.core import latency_model as lm
    from repro.core import schedules as sch
    from repro.core.multiwrite import MultiWriteSimulator
    from repro.core.topology import two_server_cluster
    rows = []
    for batch in (64, 1024):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        routing = sch.make_routing(batch, 16, 64, 8, seed=1)
        t0 = time.monotonic()
        sch.dispatch_multiwrite(sim, routing, lm.TOKEN_BYTES)
        rows.append({"name": f"sim_dispatch_mw_b{batch}", "metric": "s",
                     "value": time.monotonic() - t0})
    return rows


def bench_planner():
    """Planner sweep: which registered plan wins per payload cell, and the
    predicted-vs-baseline latency delta (the Fig 7 / Fig 8 decisions as
    planner output rather than hand-picked schemes)."""
    from repro.core import latency_model as lm
    from repro.core import planner as pl
    from repro.core.topology import split_tp_full_mesh, two_server_cluster
    rows = []
    planner = pl.Planner()
    topo, _ = split_tp_full_mesh(8, tp=4)
    print("\n== planner: §3.1 AllGather (Fig 7 cells) ==")
    print(f"{'frag':>10} {'winner':<20} {'split':>6} "
          f"{'pred us':>9} {'base us':>9} {'delta%':>7}")
    for frag in lm.FIG7_MESSAGE_BYTES:
        d = planner.choose("allgather", frag, topo)
        print(f"{frag/2**20:8.2f}MB {d.plan:<20} {d.knob('split', 1.0):>6} "
              f"{d.predicted_s*1e6:9.1f} {d.baseline_s*1e6:9.1f} "
              f"{d.speedup_pct:7.1f}")
        rows.append({"name": f"planner_ag_{frag//2**10}kb_{d.plan}",
                     "metric": "delta_vs_baseline_us",
                     "value": d.delta_vs_baseline * 1e6})
    xover = pl.emergent_crossover_bytes(topo, planner=planner)
    print(f"emergent crossover: {xover/2**20:.2f} MB (paper: ~2 MB)")
    rows.append({"name": "planner_ag_crossover", "metric": "bytes",
                 "value": xover})
    print("\n== planner: §3.2 dispatch (Fig 8 cells) ==")
    topo2 = two_server_cluster()
    for batch in lm.FIG8_BATCHES:
        d = planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo2,
                           token_bytes=lm.TOKEN_BYTES)
        print(f"batch {batch:>5}: {d.plan:<10} "
              f"pred={d.predicted_s*1e6:9.1f}us "
              f"base={d.baseline_s*1e6:9.1f}us ({d.speedup_pct:+.1f}%)")
        rows.append({"name": f"planner_disp_b{batch}_{d.plan}",
                     "metric": "delta_vs_baseline_us",
                     "value": d.delta_vs_baseline * 1e6})
    ci = planner.cache_info()
    rows.append({"name": "planner_cache_hit_rate", "metric": "ratio",
                 "value": ci["hits"] / max(1, ci["hits"] + ci["misses"])})
    return rows


def bench_fabrics(smoke: bool = False):
    """Topology-general planner sweep over the registered fabric family.

    Two parts:

    1. SMOKE (always, and the only part under ``--smoke`` — CI runs it):
       every registered plan must ``simulate`` + score on every registered
       fabric's default scenario, tiny payloads.  Any raise fails the run.
    2. Crossover table: how the Fig 7-style AllGather crossover and the
       Fig 8-style dispatch/combine flip batches move as inter-server
       bandwidth, server count, rail count and asymmetry vary.
    """
    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import FABRICS, get_fabric
    rows = []

    failures = []
    pairs = 0
    for fname in sorted(FABRICS):
        topo = get_fabric(fname)
        scenarios = plan_ir.default_scenarios(topo)
        for (op, pname), plan in sorted(plan_ir.PLAN_REGISTRY.items()):
            pairs += 1
            try:
                ledger = plan.simulate(scenarios[op], 1 << 16)
                t = lm.score_ledger(ledger)
                assert t >= 0.0, t
            except Exception as e:  # noqa: BLE001 — the smoke's whole point
                failures.append(
                    f"{op}/{pname} on {fname}: {type(e).__name__}: {e}")
    if failures:
        for f in failures:
            print(f"FABRIC SMOKE FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"fabric smoke: {pairs} (plan x fabric) pairs simulate OK "
          f"({len(FABRICS)} fabrics: {', '.join(sorted(FABRICS))})")
    rows.append({"name": "fabric_smoke_pairs", "metric": "count",
                 "value": pairs})
    if smoke:
        return rows

    from repro.core.topology import split_tp_full_mesh
    planner = pl.Planner()
    sweep = [
        # Fig 7 fixture with the (cross-domain) link bandwidth swept: the
        # AllGather crossover moves as the §3.1 links slow down
        "mesh8@56", "mesh8@25", "mesh8@12.5",
        # inter-server bandwidth sweep on the paper's 2x8 shape: the
        # Fig 8 dispatch/combine flip points move with where the
        # bottleneck sits.  (The §3.1 paired-relay AllGather correctly
        # never pays here: a rail fabric has no idle cross links to
        # relay through — crossover column reads 'never'.)
        "2x8@6.25", "2x8@12.5", "2x8", "2x8@50",
        # server count, rail count, asymmetry
        "4x8", "4x8@12.5", "2x8r2", "2x8r2@12.5", "2x8asym", "tpu_2x16",
    ]
    print("\n== bench_fabrics: crossover table (planner decisions) ==")
    print(f"{'fabric':<12} {'ag xover MB':>12} {'disp flip':>10} "
          f"{'comb flip':>10}")
    for spec in sweep:
        if spec.startswith("mesh8@"):
            bw = float(spec.split("@")[1]) * 1e9
            topo, _ = split_tp_full_mesh(8, tp=4, link_bw=bw)
            topo.name = spec
        else:
            topo = get_fabric(spec)
        xover = pl.emergent_crossover_bytes(topo, planner=planner)
        dflip = pl.emergent_flip_batch("dispatch", topo, planner=planner)
        cflip = pl.emergent_flip_batch("combine", topo, planner=planner)
        xs = f"{xover/2**20:.2f}" if xover != float("inf") else "never"
        ds = f"{dflip:.0f}" if dflip != float("inf") else "never"
        cs = f"{cflip:.0f}" if cflip != float("inf") else "never"
        print(f"{spec:<12} {xs:>12} {ds:>10} {cs:>10}")
        rows.append({"name": f"fabrics_{spec}_ag_crossover",
                     "metric": "bytes", "value": xover})
        rows.append({"name": f"fabrics_{spec}_dispatch_flip",
                     "metric": "batch", "value": dflip})
        rows.append({"name": f"fabrics_{spec}_combine_flip",
                     "metric": "batch", "value": cflip})
    return rows


def bench_calibration(smoke: bool = False):
    """End-to-end telemetry loop demo (probe -> store -> fit -> re-plan).

    Story: a healthy 2x8 cluster is probed and fitted (the fitted
    per-class bandwidths must reproduce the nominal 56/25 GB/s); then
    the inter-server rails silently degrade 4x (simulated ground truth —
    the planner never sees it, only measured times).  The drift monitor
    detects predicted-vs-measured divergence, re-fits, recalibrates the
    planner — and the dispatch flip batch moves, flipping the decision
    at the probe batch WITHOUT process restart.

    Under ``--smoke`` this is the CI gate: any broken stage of the loop
    (fit confidence, drift trip, cache invalidation, decision flip)
    exits nonzero.  Full mode also emits results/BENCH_calibration.json.
    """
    import json
    import os

    from repro.core import latency_model as lm
    from repro.core import planner as pl
    from repro.core.topology import two_server_cluster
    from repro.telemetry import (CalibrationStore, DriftMonitor,
                                 GroundTruth, SimProbe)

    topo = two_server_cluster()
    planner = pl.Planner()
    store = CalibrationStore(":memory:")
    monitor = DriftMonitor(planner, store, topo, threshold=0.25)
    probe_batch = 64                      # unicast pre, multiwrite post

    def flips():
        return (pl.emergent_flip_batch("dispatch", topo, planner=planner),
                pl.emergent_flip_batch("combine", topo, planner=planner))

    def fitted_bws(event):
        return {c: f["bw_gbps"] for c, f in (event or {}).get(
            "fits", {}).items() if f["trusted"]}

    # ---- phase 1: healthy fabric -------------------------------------------
    healthy = SimProbe(GroundTruth(noise=0.01))
    ev1 = monitor.run_cycle(healthy) or monitor.recalibrate(force=True)
    bw1 = fitted_bws(ev1)
    d_pre = planner.choose("dispatch", probe_batch * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES)
    dflip1, cflip1 = flips()
    print("== bench_calibration: telemetry loop ==")
    print(f"healthy fit: intra {bw1.get('intra', 0):.1f} GB/s "
          f"(nominal 56), inter {bw1.get('inter', 0):.1f} GB/s "
          f"(nominal 25); dispatch@{probe_batch} -> {d_pre.plan}, "
          f"flip batch {dflip1:.0f}")

    # ---- phase 2: rails silently degrade 4x --------------------------------
    degraded = SimProbe(GroundTruth(noise=0.01, seed=1).degraded(topo, 4.0))
    ev2 = None
    cycles = 0
    for cycles in range(1, 4):
        ev2 = monitor.run_cycle(degraded)
        if ev2:
            break
    bw2 = fitted_bws(ev2)
    d_post = planner.choose("dispatch", probe_batch * lm.TOKEN_BYTES, topo,
                            token_bytes=lm.TOKEN_BYTES)
    dflip2, cflip2 = flips()
    print(f"4x rail degradation: drift {100 * (ev2 or {}).get('drift', 0):.0f}% "
          f"tripped after {cycles} cycle(s); refit inter "
          f"{bw2.get('inter', 0):.2f} GB/s (true 6.25); "
          f"dispatch@{probe_batch} -> {d_post.plan}, "
          f"flip batch {dflip2:.0f}")

    # ---- the loop must actually close --------------------------------------
    failures = []
    if not (0.9 * 25 <= bw1.get("inter", 0) <= 1.1 * 25):
        failures.append(f"healthy inter fit off: {bw1}")
    if not (0.9 * 56 <= bw1.get("intra", 0) <= 1.1 * 56):
        failures.append(f"healthy intra fit off: {bw1}")
    if ev2 is None:
        failures.append("monitor never tripped on 4x degradation")
    if not (0.8 * 6.25 <= bw2.get("inter", 0) <= 1.2 * 6.25):
        failures.append(f"degraded inter fit off: {bw2}")
    if not (d_pre.plan == "unicast" and d_post.plan == "multiwrite"):
        failures.append(
            f"decision did not flip: {d_pre.plan} -> {d_post.plan}")
    if not dflip2 < dflip1:
        failures.append(f"flip batch did not move: {dflip1} -> {dflip2}")
    if planner.recalibrations < 1:
        failures.append("planner cache never invalidated")
    for f in failures:
        print(f"CALIBRATION LOOP FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    print(f"loop closed: {planner.recalibrations} recalibration(s), "
          f"{len(store)} probe records, decision flipped in-process")

    rows = [
        {"name": "calib_healthy_inter_gbps", "metric": "GB/s",
         "value": bw1.get("inter", 0.0)},
        {"name": "calib_healthy_intra_gbps", "metric": "GB/s",
         "value": bw1.get("intra", 0.0)},
        {"name": "calib_degraded_inter_gbps", "metric": "GB/s",
         "value": bw2.get("inter", 0.0)},
        {"name": "calib_drift_at_trip", "metric": "ratio",
         "value": (ev2 or {}).get("drift", 0.0)},
        {"name": "calib_dispatch_flip_pre", "metric": "batch",
         "value": dflip1},
        {"name": "calib_dispatch_flip_post", "metric": "batch",
         "value": dflip2},
        {"name": "calib_combine_flip_pre", "metric": "batch",
         "value": cflip1},
        {"name": "calib_combine_flip_post", "metric": "batch",
         "value": cflip2},
    ]
    if not smoke:
        out = {
            "run_meta": run_metadata(topo.name),
            "fabric": topo.name,
            "probe_batch": probe_batch,
            "healthy": {"fits_gbps": bw1, "dispatch_plan": d_pre.plan,
                        "dispatch_flip": dflip1, "combine_flip": cflip1},
            "degraded_4x": {"fits_gbps": bw2, "dispatch_plan": d_post.plan,
                            "dispatch_flip": dflip2, "combine_flip": cflip2,
                            "drift_at_trip": (ev2 or {}).get("drift"),
                            "cycles_to_trip": cycles},
            "recalibrations": planner.recalibrations,
            "store_records": len(store),
        }
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_calibration.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_overlap(smoke: bool = False):
    """Serial vs pipelined dispatch crossover (the overlap-aware scoring
    mode end-to-end).

    For each decode/prefill batch on the paper's 2x8 fabric, the planner
    scores every (plan, microbatch G) cell with the expert-FFN compute
    of the batch as overlap context.  The table shows the G == 1 serial
    optimum next to the pipelined optimum and the full G-sweep: small
    batches stay serial (the per-chunk launch alpha dominates), large
    batches pick G > 1 because chunked dispatch/combine hide behind the
    previous chunk's compute.  A second stage closes the telemetry loop:
    synthetic measured times at a hidden true overlap efficiency are fed
    into the planner's decision log and ``fit_overlap_eff`` must recover
    the hidden value.

    Under ``--smoke`` this is the CI gate: the crossover must exist, the
    pipelined score must beat serial there, the smallest batch must stay
    G == 1, and the efficiency fit must land near the injected truth.
    Full mode also emits results/BENCH_overlap.json.
    """
    import json
    import os

    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import two_server_cluster
    from repro.telemetry import fit_overlap_eff

    topo = two_server_cluster()
    planner = pl.Planner()
    top_k, d_model, f_shard = 8, 7168, 2048   # DeepSeek-class expert FFN
    batches = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
    g_grid = sorted({dict(kn).get("microbatch", 1)
                     for p in plan_ir.plans_for("dispatch")
                     for kn in p.knob_grid()})

    rows, table = [], []
    crossover = None
    print("\n== bench_overlap: serial vs pipelined dispatch (2x8) ==")
    print(f"{'batch':>6} {'serial us':>10} {'pipelined us':>13} {'G':>3} "
          f"{'plan':<10} {'gain%':>6}  " +
          " ".join(f"G={g:<2}" + " " * 6 for g in g_grid))
    for batch in batches:
        compute_s = lm.expert_compute_time_s(batch, top_k, d_model, f_shard)
        d = planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES, compute_s=compute_s)
        by_g: dict = {}
        for pname, kn, t in d.candidates:
            g = dict(kn).get("microbatch", 1)
            if g not in by_g or t < by_g[g][1]:
                by_g[g] = (pname, t)
        serial_t = by_g[1][1]
        gain = 100.0 * (1.0 - d.predicted_s / serial_t)
        if d.microbatch > 1 and crossover is None:
            crossover = batch
        sweep = " ".join(f"{by_g[g][1]*1e6:8.1f}" for g in g_grid)
        print(f"{batch:>6} {serial_t*1e6:>10.1f} {d.predicted_s*1e6:>13.1f} "
              f"{d.microbatch:>3} {d.plan:<10} {gain:>6.1f}  {sweep}")
        table.append({"batch": batch, "plan": d.plan, "g": d.microbatch,
                      "serial_us": serial_t * 1e6,
                      "pipelined_us": d.predicted_s * 1e6,
                      "gain_pct": gain, "compute_us": compute_s * 1e6,
                      "g_sweep_us": {g: by_g[g][1] * 1e6 for g in by_g}})
        rows.append({"name": f"overlap_b{batch}_g", "metric": "chunks",
                     "value": d.microbatch})
        rows.append({"name": f"overlap_b{batch}_gain", "metric": "pct",
                     "value": gain})
    print(f"serial->pipelined crossover batch: {crossover}")
    rows.append({"name": "overlap_crossover_batch", "metric": "batch",
                 "value": float(crossover or float("inf"))})

    # ---- close the loop: fit overlap_eff from measured decision rows ----
    true_eta = 0.6
    n_meas = 0
    for batch in (512, 1024, 2048, 4096):
        compute_s = lm.expert_compute_time_s(batch, top_k, d_model, f_shard)
        d = planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES, compute_s=compute_s)
        if d.microbatch <= 1:
            continue
        measured = (d.predicted_serial_s
                    - true_eta * (d.predicted_serial_s - d.predicted_ideal_s))
        planner.note_measurement(d, measured)
        n_meas += 1
    eta_fit = fit_overlap_eff(planner.decision_log)
    print(f"overlap_eff fit: {eta_fit} from {n_meas} measured pipelined "
          f"decisions (true {true_eta})")
    rows.append({"name": "overlap_eff_fit", "metric": "ratio",
                 "value": eta_fit if eta_fit is not None else -1.0})

    # ---- the knob must actually win (CI gate) -------------------------------
    failures = []
    if crossover is None:
        failures.append("planner never chose microbatch > 1")
    else:
        best = next(r for r in table if r["batch"] == crossover)
        if not best["pipelined_us"] < best["serial_us"]:
            failures.append(f"pipelined did not beat serial at {crossover}")
    if table[0]["g"] != 1:
        failures.append(f"smallest batch chunked: G={table[0]['g']} "
                        "(per-chunk alpha should keep it serial)")
    if eta_fit is None or abs(eta_fit - true_eta) > 0.05:
        failures.append(f"overlap_eff fit {eta_fit} != true {true_eta}")
    for f in failures:
        print(f"OVERLAP GATE FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)

    if not smoke:
        out = {"run_meta": run_metadata(topo.name),
               "fabric": topo.name, "token_bytes": lm.TOKEN_BYTES,
               "top_k": top_k, "d_model": d_model, "f_shard": f_shard,
               "crossover_batch": crossover, "cells": table,
               "overlap_eff_fit": {"fitted": eta_fit, "true": true_eta,
                                   "n_measured": n_meas}}
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_overlap.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_program(smoke: bool = False):
    """Joint whole-program planning vs the PR-4 dispatch-first path.

    For each (fabric, batch) cell, two plans of the SAME MoE round trip:

    * dispatch-first — the dispatch op sweeps alone, the pipeline runs
      its G, the combine scheme is compared at that executed G (how
      moe_ffn resolved before the ExecutionPlan redesign);
    * joint — ``Planner.plan_program`` sweeps the (dispatch scheme) x
      (combine scheme) x (shared G) product under the shared-pipeline
      scorer (``score_pipeline``).

    Both configurations are scored with the same combined model, so the
    table shows exactly what joint planning buys: cells where a SMALLER
    dispatch G (or a different scheme pair) wins on the combined score.

    CI gates (also under ``--smoke``): the joint score must never lose
    to dispatch-first; at least one cell must genuinely change the
    (dispatch G, combine G) decision and strictly win; ExecutionPlan
    fingerprints must be deterministic across fresh planners.  Full mode
    emits results/BENCH_program.json.
    """
    import json
    import os

    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import get_fabric

    top_k, d_model, f_shard = 8, 7168, 2048   # DeepSeek-class expert FFN
    fabrics = ("2x8",) if smoke else ("2x8", "2x8@50", "2x8asym", "4x8")
    batches = ((64, 256, 1024, 2048) if smoke
               else (64, 128, 256, 512, 1024, 2048, 4096))

    def scheme_of(plan_name):
        return "hierarchical" if plan_name == "multiwrite" else "baseline"

    def dispatch_first(planner, topo, batch, compute_s):
        """The PR-4 resolution: dispatch alone, combine at its G."""
        d = planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES,
                           compute_s=compute_s)
        g = d.microbatch
        c = planner.choose("combine", batch * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES,
                           compute_s=compute_s)
        c_name = min((t, name) for name, kn, t in c.candidates
                     if dict(kn).get("microbatch", 1) == g)[1]
        if d.plan == "unicast":
            c_name = "unicast"             # executable pairing
        scen_kw = dict(num_experts=64, top_k=top_k,
                       token_bytes=lm.TOKEN_BYTES, skew=0.0,
                       compute_s=compute_s)
        bucket = pl.bucket_payload(batch * lm.TOKEN_BYTES)
        ld = plan_ir.get_plan("dispatch", d.plan).simulate(
            pl.Planner._scenario("dispatch", topo, scen_kw), bucket,
            microbatch=g)
        lc = plan_ir.get_plan("combine", c_name).simulate(
            pl.Planner._scenario("combine", topo, scen_kw), bucket,
            microbatch=g)
        t = lm.score_pipeline((ld, lc), planner.hw)
        return (d.plan, g, c_name), t

    def joint_cell(planner, topo, batch, compute_s):
        sites = plan_ir.moe_sites("bench", num_experts=64, top_k=top_k,
                                  tokens_per_rank=batch,
                                  token_bytes=lm.TOKEN_BYTES,
                                  compute_s=compute_s)
        eplan = planner.plan_program(
            plan_ir.CollectiveProgram("bench", sites), topo)
        return eplan, eplan.joint["bench/moe_dispatch"]

    rows, table, failures, changed = [], [], [], 0
    print("\n== bench_program: joint vs dispatch-first planning ==")
    print(f"{'fabric':<9} {'batch':>6} {'dispatch-first':<28} "
          f"{'joint':<28} {'first us':>9} {'joint us':>9} {'gain%':>6}")
    for fname in fabrics:
        topo = get_fabric(fname)
        planner = pl.Planner()
        for batch in batches:
            compute_s = lm.expert_compute_time_s(batch, top_k, d_model,
                                                 f_shard)
            (dp, g1, cp), first_t = dispatch_first(planner, topo, batch,
                                                   compute_s)
            eplan, joint = joint_cell(planner, topo, batch, compute_s)
            kw = joint.shard_map_kwargs
            gj = joint.microbatch
            pair_first = (scheme_of(dp), g1, scheme_of(cp), g1)
            pair_joint = (kw["moe_scheme"], gj, kw["moe_combine"], gj)
            gain = 100.0 * (1.0 - joint.predicted_s / first_t)
            moved = pair_joint != pair_first
            changed += moved
            if joint.predicted_s > first_t * (1 + 1e-9):
                failures.append(
                    f"{fname} b{batch}: joint {joint.predicted_s:.2e}s "
                    f"lost to dispatch-first {first_t:.2e}s")
            if moved and not joint.predicted_s < first_t:
                failures.append(
                    f"{fname} b{batch}: decision moved without a win")
            first_s = f"{dp}@G{g1} + {cp}@G{g1}"
            joint_s = (f"{kw['moe_scheme'][:4]}@G{gj} + "
                       f"{kw['moe_combine'][:4]}@G{gj}"
                       f"{' *' if moved else ''}")
            print(f"{fname:<9} {batch:>6} {first_s:<28} {joint_s:<28} "
                  f"{first_t*1e6:>9.1f} {joint.predicted_s*1e6:>9.1f} "
                  f"{gain:>6.2f}")
            table.append({
                "fabric": fname, "batch": batch,
                "dispatch_first": {"pair": pair_first,
                                   "combined_us": first_t * 1e6},
                "joint": {"pair": pair_joint,
                          "combined_us": joint.predicted_s * 1e6,
                          "fingerprint": eplan.fingerprint},
                "changed": moved, "gain_pct": gain})
            rows.append({"name": f"program_{fname}_b{batch}_gain",
                         "metric": "pct", "value": gain})
    print(f"cells where joint planning changed the decision: {changed}/"
          f"{len(table)}")
    rows.append({"name": "program_cells_changed", "metric": "count",
                 "value": changed})

    # fingerprint determinism across fresh planners
    topo = get_fabric(fabrics[0])
    compute_s = lm.expert_compute_time_s(batches[-1], top_k, d_model,
                                         f_shard)
    fp_a = joint_cell(pl.Planner(), topo, batches[-1],
                      compute_s)[0].fingerprint
    fp_b = joint_cell(pl.Planner(), topo, batches[-1],
                      compute_s)[0].fingerprint
    if fp_a != fp_b:
        failures.append(f"non-deterministic fingerprints: {fp_a} != {fp_b}")

    if not changed:
        failures.append("joint planning never changed a (dispatch G, "
                        "combine G) decision vs dispatch-first")
    for f in failures:
        print(f"PROGRAM GATE FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)

    if not smoke:
        out = {"run_meta": run_metadata(),
               "token_bytes": lm.TOKEN_BYTES, "top_k": top_k,
               "d_model": d_model, "f_shard": f_shard,
               "cells": table, "cells_changed": changed,
               "fingerprint_deterministic": True}
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_program.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_allreduce(smoke: bool = False):
    """Gradient-sync scheme crossover: scheme x payload x fabric.

    For each (fabric, payload) cell, which registered allreduce /
    reduce_scatter scheme ``Planner.choose`` picks (executable schemes
    only — the set a trainer may auto-bind), and where the crossover
    between the latency-optimal tree and the bandwidth-optimal
    relay-reduce multiwrite sits on each fabric.

    CI gates (also under ``--smoke``):
      * >= 2 distinct allreduce schemes win across the sweep (the
        crossover is emergent, not a registry accident);
      * every registered reduce plan simulates to a finite positive
        score on every registered fabric;
      * the lossy compressed plan is never auto-bound;
      * the pipelined (chunked, overlap-aware) grad-sync decision beats
        its own serial score AND the ring baseline on 2x8 — the backward
        pass genuinely hides wire time.
    Full mode emits results/BENCH_allreduce.json.
    """
    import json
    import math
    import os

    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import FABRICS, get_fabric

    fabrics = ("2x8", "tpu_2x16") if smoke else tuple(FABRICS)
    payloads = ([1 << p for p in (16, 20, 24)] if smoke
                else [1 << p for p in range(16, 29, 2)])

    rows, table, failures = [], [], []
    winners = set()
    print("\n== bench_allreduce: gradient-sync scheme crossover ==")
    print(f"{'fabric':<9} " + " ".join(f"{p >> 10:>9}K" if p < 1 << 20
                                       else f"{p >> 20:>9}M"
                                       for p in payloads))
    for fname in fabrics:
        topo = get_fabric(fname)
        planner = pl.Planner()
        line = []
        for payload in payloads:
            d = planner.choose("allreduce", float(payload), topo,
                               executable_only=True)
            winners.add(d.plan)
            if d.plan == "compressed":
                failures.append(f"{fname} {payload}: lossy compressed "
                                f"auto-bound")
            rs = planner.choose("reduce_scatter", float(payload), topo,
                                executable_only=True)
            line.append(d.plan)
            table.append({
                "fabric": fname, "payload_bytes": payload,
                "allreduce": d.report(), "reduce_scatter": rs.report()})
            rows.append({"name": f"allreduce_{fname}_{payload}_speedup",
                         "metric": "pct",
                         "value": 100.0 * (1 - d.predicted_s
                                           / d.baseline_s)})
        print(f"{fname:<9} " + " ".join(f"{s:>10}" for s in line))

    # simulate-everywhere gate: every reduce plan on every fabric
    for fname in FABRICS:
        topo = get_fabric(fname)
        scen = plan_ir.default_scenarios(topo)
        for op in ("allreduce", "reduce_scatter"):
            for p in plan_ir.plans_for(op):
                led = p.simulate_fn(scen[op], 1 << 20, microbatch=1)
                t = pl.score_ledger(led, lm.DEFAULT)
                if not (t > 0 and math.isfinite(t)):
                    failures.append(f"{fname}/{op}/{p.name}: bad score {t}")

    if len(winners) < 2:
        failures.append(f"only one allreduce scheme ever wins: {winners}")

    # pipelined grad-sync gate on 2x8: a 12B-param fp32 gradient sync,
    # TP=8, with the modeled backward tail as overlap context
    topo = get_fabric("2x8")
    num_params, tp = 12_000_000_000, 8
    site = plan_ir.grad_sync_site(
        "train", payload_bytes=num_params * 4 / tp,
        compute_s=lm.backward_compute_s(num_params, 2048, tp=tp),
        topo=topo)
    eplan = pl.Planner().plan_program(
        plan_ir.CollectiveProgram("train", (site,)), topo)
    gs = eplan.decisions["train/grad_sync"]
    g = gs.shard_map_kwargs["microbatch"]
    print(f"grad_sync on 2x8: {gs.plan} G={g} serial "
          f"{gs.predicted_serial_s * 1e3:.2f}ms -> pipelined "
          f"{gs.predicted_s * 1e3:.2f}ms (ring baseline "
          f"{gs.baseline_s * 1e3:.2f}ms)")
    if g <= 1:
        failures.append("grad_sync never chunks on 2x8 (G == 1)")
    if not gs.predicted_s < gs.predicted_serial_s:
        failures.append("pipelined grad_sync does not beat serial on 2x8")
    if not gs.predicted_s < gs.baseline_s:
        failures.append("grad_sync does not beat the ring baseline on 2x8")
    rows.append({"name": "grad_sync_2x8_pipelined_gain", "metric": "pct",
                 "value": 100.0 * (1 - gs.predicted_s
                                   / gs.predicted_serial_s)})

    for f in failures:
        print(f"ALLREDUCE GATE FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)

    if not smoke:
        out = {"run_meta": run_metadata(",".join(fabrics)),
               "fabrics": list(fabrics),
               "payloads": payloads,
               "winners": sorted(winners),
               "grad_sync_2x8": gs.report(),
               "cells": table}
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_allreduce.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_contention(smoke: bool = False):
    """Contention-aware whole-program planning vs independent per-site
    planning, plus the beam-search cost/quality envelope.

    Part 1 — flip sweep: for each (fabric, MoE batch, grad payload) cell
    a single ``train`` phase declares the coupled MoE (dispatch, combine)
    pair AND the gradient-sync allreduce on the SAME fabric.  The greedy
    assignment (every group's own contention-free best — exactly what
    independent per-site planning binds) is re-scored under the shared
    -link phase scorer and compared against ``plan_program``'s jointly
    searched combination.  A cell "flips" when the joint search picks a
    different (scheme, G) set with a strictly better contended score.

    Part 2 — beam envelope: a 3-group ``tpu_2x16`` program (MoE pair +
    grad sync + split-TP gather in one phase) whose candidate product
    exceeds ``Planner.EXHAUSTIVE_LIMIT``.  Beam search must enumerate
    < 10% of the exhaustive product while landing within 2% of the
    forced-exhaustive oracle score, inside a planning wall-time budget.

    CI gates (also under ``--smoke``):
      * joint search never loses to the greedy assignment;
      * >= 1 cell flips with a strict modeled win;
      * the tpu_2x16 program's product forces beam under ``auto``;
      * beam scores < 10% of the product and lands within 2% of the
        oracle;
      * beam planning wall time stays under the regression threshold.
    Full mode emits results/BENCH_contention.json.
    """
    import json
    import os

    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import get_fabric

    top_k, d_model, f_shard = 8, 7168, 2048   # DeepSeek-class expert FFN
    tp, seq = 8, 2048
    fabrics = (("2x8", "tpu_2x16") if smoke
               else ("2x8", "2x8@50", "2x8asym", "4x8", "tpu_2x16"))
    batches = (1024, 4096) if smoke else (256, 1024, 2048, 4096)
    # grad payloads from LoRA-scale to 12B dense: the flips live where
    # gradient traffic is COMPARABLE to the MoE round trip (a 12B sync
    # dwarfs everything and the same scheme wins solo and contended)
    grad_params = ((100_000_000, 1_000_000_000) if smoke
                   else (10_000_000, 100_000_000, 1_000_000_000,
                         12_000_000_000))
    PLAN_TIME_BUDGET_S = 3.0   # beam wall-time regression threshold

    def train_program(batch, n_params, extra=()):
        compute_s = lm.expert_compute_time_s(batch, top_k, d_model,
                                             f_shard)
        d, c = plan_ir.moe_sites(
            "train", num_experts=64, top_k=top_k, tokens_per_rank=batch,
            token_bytes=lm.TOKEN_BYTES, compute_s=compute_s)
        gs = plan_ir.grad_sync_site(
            "train", payload_bytes=n_params * 4 / tp,
            compute_s=lm.backward_compute_s(n_params, seq, tp=tp))
        return plan_ir.CollectiveProgram("bench_contention",
                                         (d, c, gs) + tuple(extra))

    def greedy_view(planner, program, topo):
        """Independent per-site planning: each group's own best row,
        re-scored under the shared-link phase model."""
        groups = program.phases()["train"]
        bundles = [planner._group_candidates(g, topo, planner.hw, True)
                   for g in groups]
        entries = [(b["cands"][0]["score_s"], b["cands"][0]["ledgers"])
                   for b in bundles]
        labels = []
        for b in bundles:
            r = b["rows"][0]
            if b["kind"] == "single":
                labels.append(f"{r[2].name}@G"
                              f"{dict(r[3]).get('microbatch', 1)}")
            else:
                labels.append(f"{r[2].name}+{r[5].name}@G"
                              f"{dict(r[3]).get('microbatch', 1)}")
        return lm.score_phase(entries, planner.hw), tuple(labels)

    def joint_labels(eplan):
        d = eplan.decisions["train/moe_dispatch"]
        c = eplan.decisions["train/moe_combine"]
        g = eplan.decisions["train/grad_sync"]
        return (f"{d.plan}+{c.plan}@G{d.microbatch}",
                f"{g.plan}@G{g.microbatch}")

    rows, table, failures, flips = [], [], [], 0
    print("\n== bench_contention: joint vs independent phase planning ==")
    print(f"{'fabric':<10} {'batch':>6} {'params':>6} "
          f"{'independent (greedy)':<34} {'joint':<34} "
          f"{'greedy us':>10} {'joint us':>9} {'win%':>6}")
    for fname in fabrics:
        topo = get_fabric(fname)
        planner = pl.Planner()
        for batch in batches:
            for n_params in grad_params:
                program = train_program(batch, n_params)
                greedy_s, g_labels = greedy_view(planner, program, topo)
                eplan = planner.plan_program(program, topo)
                joint_s = eplan.phase_report["train"]["score_s"]
                j_labels = joint_labels(eplan)
                moved = j_labels != g_labels
                win = 100.0 * (1.0 - joint_s / greedy_s)
                if joint_s > greedy_s * (1 + 1e-9):
                    failures.append(
                        f"{fname} b{batch} p{n_params}: joint "
                        f"{joint_s:.3e}s lost to greedy {greedy_s:.3e}s")
                if moved and not joint_s < greedy_s:
                    failures.append(
                        f"{fname} b{batch} p{n_params}: decision flipped "
                        f"without a contended win")
                flips += moved and joint_s < greedy_s
                gl = " ".join(g_labels)
                jl = " ".join(j_labels) + (" *" if moved else "")
                print(f"{fname:<10} {batch:>6} "
                      f"{f'{n_params / 1e9:g}B':>6} "
                      f"{gl:<34} {jl:<34} {greedy_s * 1e6:>10.1f} "
                      f"{joint_s * 1e6:>9.1f} {win:>6.2f}")
                table.append({
                    "fabric": fname, "batch": batch,
                    "grad_params": n_params,
                    "independent": {"labels": g_labels,
                                    "phase_us": greedy_s * 1e6},
                    "joint": {"labels": j_labels,
                              "phase_us": joint_s * 1e6,
                              "contention_us":
                                  eplan.phase_report["train"]
                                  ["contention_s"] * 1e6},
                    "flipped": moved, "win_pct": win})
                rows.append({"name": f"contention_{fname}_b{batch}"
                                     f"_p{n_params // 10**6}m_win",
                             "metric": "pct", "value": win})
    print(f"cells where joint contention scoring flipped the decision: "
          f"{flips}/{len(table)}")
    rows.append({"name": "contention_cells_flipped", "metric": "count",
                 "value": flips})
    if not flips:
        failures.append("joint contention scoring never flipped a "
                        "decision vs independent per-site planning")

    # ---- part 2: beam search envelope on the wide tpu_2x16 program ----
    topo = get_fabric("tpu_2x16")
    wide = train_program(
        2048, 12_000_000_000,
        extra=(plan_ir.allgather_site("train", frag_bytes=8 << 20),))
    e_beam = pl.Planner(search="beam").plan_program(wide, topo)
    e_oracle = pl.Planner(search="exhaustive").plan_program(wide, topo)
    e_auto = pl.Planner().plan_program(wide, topo)
    sb, so = e_beam.planner_stats, e_oracle.planner_stats
    beam_s = e_beam.phase_report["train"]["score_s"]
    oracle_s = e_oracle.phase_report["train"]["score_s"]
    gap = 100.0 * (beam_s - oracle_s) / oracle_s
    frac = sb["combos_scored"] / max(1, sb["product"])
    print(f"\ntpu_2x16 wide program (3 groups): product {sb['product']}, "
          f"beam scored {sb['combos_scored']} ({100 * frac:.1f}%) in "
          f"{sb['planning_wall_s'] * 1e3:.1f}ms; oracle scored "
          f"{so['combos_scored']} in {so['planning_wall_s'] * 1e3:.1f}ms; "
          f"beam {beam_s * 1e6:.1f}us vs oracle {oracle_s * 1e6:.1f}us "
          f"(gap {gap:+.2f}%)")
    if sb["product"] <= pl.Planner.EXHAUSTIVE_LIMIT:
        failures.append(f"wide program product {sb['product']} does not "
                        f"exceed EXHAUSTIVE_LIMIT "
                        f"{pl.Planner.EXHAUSTIVE_LIMIT}")
    if e_auto.planner_stats["search"] != ["beam"]:
        failures.append(f"auto mode did not pick beam on the wide "
                        f"program: {e_auto.planner_stats['search']}")
    if not frac < 0.10:
        failures.append(f"beam scored {100 * frac:.1f}% of the product "
                        f"(gate: < 10%)")
    if not gap <= 2.0:
        failures.append(f"beam landed {gap:.2f}% off the oracle "
                        f"(gate: <= 2%)")
    if not sb["planning_wall_s"] < PLAN_TIME_BUDGET_S:
        failures.append(f"beam planning took "
                        f"{sb['planning_wall_s']:.2f}s (budget "
                        f"{PLAN_TIME_BUDGET_S}s) on tpu_2x16")
    rows.append({"name": "contention_beam_scored_frac", "metric": "ratio",
                 "value": frac})
    rows.append({"name": "contention_beam_oracle_gap", "metric": "pct",
                 "value": gap})
    rows.append({"name": "contention_beam_wall_ms", "metric": "ms",
                 "value": sb["planning_wall_s"] * 1e3})

    for f in failures:
        print(f"CONTENTION GATE FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)

    if not smoke:
        out = {"run_meta": run_metadata("tpu_2x16"),
               "token_bytes": lm.TOKEN_BYTES, "top_k": top_k,
               "d_model": d_model, "f_shard": f_shard, "tp": tp,
               "cells": table, "cells_flipped": flips,
               "beam_envelope": {
                   "fabric": "tpu_2x16",
                   "product": sb["product"],
                   "combos_scored": sb["combos_scored"],
                   "scored_frac": frac,
                   "beam_us": beam_s * 1e6,
                   "oracle_us": oracle_s * 1e6,
                   "gap_pct": gap,
                   "beam_wall_ms": sb["planning_wall_s"] * 1e3,
                   "oracle_wall_ms": so["planning_wall_s"] * 1e3,
                   "wall_budget_s": PLAN_TIME_BUDGET_S}}
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_contention.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_train_throughput():
    """Tiny-model CPU train-step wall time (framework overhead check)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM, batch_for_model
    from repro.models.api import build_model
    from repro.optim import adamw
    from repro.runtime.trainer import TrainState, make_train_step
    cfg = get_config("mistral_nemo_12b").reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=256)
    model = build_model(cfg, dtype=jnp.float32)
    opt = adamw(lr=1e-3)
    params = model.init(jax.random.key(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    data = SyntheticLM(DataConfig(vocab=256, seq_len=64, global_batch=8))
    step = make_train_step(model, opt, donate=False)
    batch = batch_for_model(cfg, data.batch(0))
    state, _ = step(state, batch)                     # compile
    t0 = time.monotonic()
    m = None
    for i in range(5):
        state, m = step(state, batch_for_model(cfg, data.batch(i + 1)))
    jax.block_until_ready(m)
    return [{"name": "train_step_smoke_cpu", "metric": "s/step",
             "value": (time.monotonic() - t0) / 5}]


def bench_failover(smoke: bool = False):
    """Fault-tolerance latency: how fast the detect -> replan -> hot
    re-bind arc turns a dark rail into a feasible running plan, and what
    the degraded fabric costs against the healthy one.

    Two tables:

    1. Time-to-reroute — one rail of the 2x8 fabric goes dark (both
       directions); a ``FailureDetector``-equipped ``DriftMonitor``
       scans, declares the rail dead after ``strikes`` consecutive
       timeouts, retargets the bound program, and a ``PlanBinder``
       stages the replacement off the step path.  Measured: scan cycles
       to declare, wall time of the declaring cycle, a cold
       ``plan_program`` replan on the degraded fabric, stage (build)
       time and the swap (pointer-flip) time.

    2. Degraded vs healthy — planner-predicted latency per op x payload
       on the healthy fabric vs the one-rail-dark fabric, with the
       winning scheme on each side (reroutes show up as plan flips, the
       ratio is the multicast capacity the dark rail took with it).

    CI gates (also under ``--smoke``):

      - detection happens in exactly ``strikes`` scan cycles;
      - every site ledger of the retargeted plan is feasible under the
        injected failure state (nothing charges the dark rail);
      - the staged swap performs zero cold retraces;
      - no degraded op gets *faster* than healthy (ratio >= 1 - 1e-9).

    Full mode emits results/BENCH_failover.json.
    """
    import json
    import os

    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core import schedules  # noqa: F401 — registers plans
    from repro.core.topology import FailureState, get_fabric
    from repro.parallel.context import PlanBinder
    from repro.telemetry import (CalibrationStore, DriftMonitor,
                                 FailureDetector, GroundTruth,
                                 ProbePolicy, SimProbe,
                                 reset_default_registry)

    reset_default_registry()
    topo = get_fabric("2x8")
    planner = pl.Planner()
    program = plan_ir.CollectiveProgram(
        "bench_failover",
        sites=plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                                tokens_per_rank=64, token_bytes=7168))

    # -- arc: dark rail -> declared -> retargeted -> staged -> swapped --
    policy = ProbePolicy(retries=0, backoff_s=0.0, jitter=0.0,
                         sleep=lambda s: None)
    detector = FailureDetector(topo, strikes=2, policy=policy)
    monitor = DriftMonitor(planner, CalibrationStore(":memory:"), topo,
                           detector=detector)
    eplan = planner.plan_program(program, topo)
    binder = PlanBinder(lambda plan: ("lowered", plan.fingerprint),
                        plan=eplan)
    rail = detector.rails[0]
    dark = SimProbe(GroundTruth(seed=3).with_dead(
        [rail, (rail[1], rail[0])]))
    cycles = 0
    t_detect = 0.0
    while not detector.dead_links():
        t0 = time.monotonic()
        monitor.run_cycle(dark)
        t_detect = time.monotonic() - t0      # the declaring cycle
        cycles += 1
        assert cycles <= 8, "detector never declared the dark rail"
    assert cycles == detector.strikes, (
        f"declared after {cycles} cycles, strikes={detector.strikes}")

    staged = monitor.staged_plan(program.name)
    assert staged is not None and staged.fingerprint != eplan.fingerprint
    failures = FailureState(dead_links=detector.dead_links())
    for role, led in pl.plan_site_ledgers(staged, monitor.topo).items():
        reason = pl.ledger_infeasible(led, failures)
        assert reason is None, f"{role}: {reason}"

    t0 = time.monotonic()
    replanner = pl.Planner()
    replanner.plan_program(program, monitor.topo)
    t_replan = time.monotonic() - t0          # cold replan, empty cache

    t0 = time.monotonic()
    binder.stage(staged)
    t_stage = time.monotonic() - t0           # off the step path
    t0 = time.monotonic()
    binder.swap_if_pending()
    t_swap = time.monotonic() - t0            # ON the step path
    assert binder.cold_retraces == 0, "swap traced at the step boundary"

    rows = [
        {"name": "failover_detect_cycles", "metric": "cycles",
         "value": cycles},
        {"name": "failover_detect_cycle_s", "metric": "s",
         "value": t_detect},
        {"name": "failover_replan_s", "metric": "s", "value": t_replan},
        {"name": "failover_stage_s", "metric": "s", "value": t_stage},
        {"name": "failover_swap_s", "metric": "s", "value": t_swap},
    ]

    # -- degraded vs healthy predicted-latency table --------------------
    degraded_topo = topo.with_failures(FailureState(
        dead_links={rail, (rail[1], rail[0])}))
    payloads = [8 << 20] if smoke else [1 << 20, 8 << 20, 64 << 20]
    table = []
    for op in ("dispatch", "allreduce", "reduce_scatter"):
        for nbytes in payloads:
            healthy = planner.choose(op, nbytes, topo,
                                     executable_only=True)
            hurt = planner.choose(op, nbytes, degraded_topo,
                                  executable_only=True)
            ratio = hurt.predicted_s / healthy.predicted_s
            assert ratio >= 1.0 - 1e-9, (
                f"{op}@{nbytes}: degraded beat healthy ({ratio:.3f})")
            table.append({
                "op": op, "payload_bytes": nbytes,
                "healthy_plan": healthy.plan,
                "healthy_s": healthy.predicted_s,
                "degraded_plan": hurt.plan,
                "degraded_s": hurt.predicted_s,
                "slowdown": ratio,
            })
            rows.append({"name": f"failover_{op}_{nbytes >> 20}mb_slowdown",
                         "metric": "x", "value": ratio})

    if not smoke:
        out = {
            "run_meta": run_metadata(topo.name),
            "fabric": topo.name,
            "dark_rail": list(rail),
            "time_to_reroute": {
                "detect_cycles": cycles,
                "detect_cycle_s": t_detect,
                "replan_s": t_replan,
                "stage_s": t_stage,
                "swap_s": t_swap,
            },
            "degraded_vs_healthy": table,
        }
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_failover.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


def bench_serving(smoke: bool = False):
    """Continuous batching vs static batching under open-loop Poisson
    traffic, and planner-informed admission vs the crossover-oblivious
    greedy-admit baseline (ISSUE 10).

    Three schedulers drain the SAME seeded arrival stream per swept
    rate, in pure virtual-time simulation (planner-predicted step
    costs, no models, deterministic on CPU):

      * ``static``      — drain-the-batch barrier: nothing is admitted
        while any cohort is in flight (the pre-PR-10 ``generate`` shape);
      * ``cont_greedy`` — iteration-level join/exit, admits every ready
        request, never consults the planner: after the decode batch
        grows past the bucket its plan was bound for, decode keeps
        executing the STALE scheme (unicast at a multiwrite-sized
        payload — exactly what crossover-oblivious admission costs);
      * ``cont_planner`` — the shipped policy: holds the batch when the
        planner predicts the grown bucket blows the TPOT SLO, and
        stages the next bucket's plan through ``PlanBinder`` ahead of
        admission (pointer-flip growth), escaping to admission under
        TTFT queue pressure.

    CI gates (also under ``--smoke``):
      * continuous beats static on p99 TTFT at >= 1 swept rate;
      * >= 1 swept rate where planner-informed admission held below the
        scheme crossover (or prefetch-rebound across it) AND beat the
        greedy baseline on BOTH p99 TTFT and p99 TPOT;
      * zero cold retraces across every plan swap (the per-run binder
        counters and the process metric delta).
    Full mode emits results/BENCH_serving.json.
    """
    import json
    import os

    from repro.core import latency_model as lm
    from repro.core import plan as plan_ir
    from repro.core.planner import default_planner
    from repro.core.topology import get_fabric
    from repro.parallel.context import PlanBinder
    from repro.serving import (AdmissionController, BatchScheduler,
                               PlannerProbe, RequestQueue, TrafficConfig,
                               TrafficGenerator)
    from repro.telemetry.metrics import default_registry

    fabric = "2x8"
    token_bytes = 2 * 7168               # bf16 activations, DeepSeek d_model
    topo = get_fabric(fabric)
    planner = default_planner()
    probe = PlannerProbe(topo, token_bytes=token_bytes)
    xover = probe.crossover_batch()
    anchor = int(xover) if xover != float("inf") else 64
    tpot_slo_s = probe.decode_step_s(anchor) * 1.15
    ttft_slo_s = 0.08
    capacity, n_requests, seed = 512, 300, 7
    rates = (500.0, 8000.0) if smoke else (250.0, 500.0, 1000.0, 2000.0,
                                           4000.0, 8000.0, 16000.0)

    # decode-phase serve program per batch bucket — what the admission
    # controller stages through the binder ahead of a bucket crossing
    bucket_plans = {}

    def plan_for_bucket(bucket):
        eplan = bucket_plans.get(bucket)
        if eplan is None:
            sites = plan_ir.moe_sites(
                "decode", num_experts=64, top_k=8, tokens_per_rank=bucket,
                token_bytes=token_bytes,
                compute_s=lm.expert_compute_time_s(bucket, 8, 7168, 2048))
            eplan = planner.plan_program(
                plan_ir.CollectiveProgram("serve", sites), topo, None)
            bucket_plans[bucket] = eplan
        return eplan

    reg = default_registry()
    cold0 = reg["repro_rebind_cold_retrace_total"].value(program="serve")

    def drain(rate, mode):
        queue = RequestQueue()
        cfg = TrafficConfig(arrival_rate_rps=rate, num_requests=n_requests,
                            prompt_lens=(128,), max_news=(16,), seed=seed)
        for r in TrafficGenerator(cfg).requests():
            queue.push(r)
        policy = "planner" if mode == "cont_planner" else "greedy"
        adm = AdmissionController(
            probe, capacity=capacity, policy=policy,
            tpot_slo_s=tpot_slo_s, ttft_slo_s=ttft_slo_s)
        binder = None
        pfb = None
        if mode == "cont_planner":
            # receipt-artifact binder: the staging/swap path is real
            # (fingerprint cache, rebind + cold-retrace metrics), only
            # the lowering is a stub — no models in the simulation
            binder = PlanBinder(
                lambda p: {"plan": None if p is None else p.fingerprint},
                plan=plan_for_bucket(1))
            pfb = plan_for_bucket
        sched = BatchScheduler(
            queue=queue, admission=adm, probe=probe, binder=binder,
            plan_for_bucket=pfb, static_batching=(mode == "static"))
        sched.run_until_drained()
        rep = sched.report(ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s)
        rep["mode"], rep["rate_rps"] = mode, rate
        return rep

    table, rows, failures = [], [], []
    for rate in rates:
        cell = {m: drain(rate, m)
                for m in ("static", "cont_greedy", "cont_planner")}
        table.extend(cell.values())
        pl, gr, st = (cell["cont_planner"], cell["cont_greedy"],
                      cell["static"])
        print(f"serving rate={rate:7.0f}/s  "
              f"static p99ttft={st['ttft_p99_s'] * 1e3:8.2f}ms  "
              f"greedy p99ttft={gr['ttft_p99_s'] * 1e3:8.2f}ms "
              f"p99tpot={gr['tpot_p99_s'] * 1e6:8.1f}us  "
              f"planner p99ttft={pl['ttft_p99_s'] * 1e3:8.2f}ms "
              f"p99tpot={pl['tpot_p99_s'] * 1e6:8.1f}us  "
              f"holds={pl['admission_holds']} "
              f"prefetch={pl['prefetch_rebinds']} "
              f"goodput={pl['goodput_rps']:.0f}/s")
        rows.append({"name": f"serving_r{rate:.0f}_planner_p99_ttft",
                     "metric": "ms", "value": pl["ttft_p99_s"] * 1e3})
        rows.append({"name": f"serving_r{rate:.0f}_planner_p99_tpot",
                     "metric": "us", "value": pl["tpot_p99_s"] * 1e6})
        rows.append({"name": f"serving_r{rate:.0f}_greedy_p99_ttft",
                     "metric": "ms", "value": gr["ttft_p99_s"] * 1e3})
        rows.append({"name": f"serving_r{rate:.0f}_static_p99_ttft",
                     "metric": "ms", "value": st["ttft_p99_s"] * 1e3})

    # gate 1: continuous beats static on p99 TTFT somewhere
    cont_wins = [r for r in table if r["mode"] == "cont_planner" and
                 r["ttft_p99_s"] < next(
                     s["ttft_p99_s"] for s in table
                     if s["mode"] == "static" and
                     s["rate_rps"] == r["rate_rps"])]
    if not cont_wins:
        failures.append("continuous batching never beat static on p99 "
                        "TTFT at any swept rate")
    # gate 2: planner-informed admission engaged AND beat greedy
    informed_wins = []
    for rate in rates:
        pl = next(r for r in table if r["mode"] == "cont_planner" and
                  r["rate_rps"] == rate)
        gr = next(r for r in table if r["mode"] == "cont_greedy" and
                  r["rate_rps"] == rate)
        engaged = pl["admission_holds"] > 0 or pl["prefetch_rebinds"] > 0
        if engaged and pl["ttft_p99_s"] < gr["ttft_p99_s"] and \
                pl["tpot_p99_s"] < gr["tpot_p99_s"]:
            informed_wins.append(rate)
    if not informed_wins:
        failures.append(
            "planner-informed admission never simultaneously engaged "
            "(hold below crossover / prefetch-rebind across it) and beat "
            "greedy-admit on p99 TTFT + TPOT")
    # gate 3: every plan swap was warm
    for r in table:
        if r.get("cold_retraces"):
            failures.append(f"{r['mode']}@{r['rate_rps']}: "
                            f"{r['cold_retraces']} cold retraces")
    cold_delta = reg["repro_rebind_cold_retrace_total"].value(
        program="serve") - cold0
    if cold_delta:
        failures.append(f"repro_rebind_cold_retrace_total grew by "
                        f"{cold_delta} during the sweep")

    for f in failures:
        print(f"SERVING GATE FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)

    if not smoke:
        out = {"run_meta": run_metadata(fabric),
               "token_bytes": token_bytes,
               "crossover_batch": xover,
               "tpot_slo_us": tpot_slo_s * 1e6,
               "ttft_slo_ms": ttft_slo_s * 1e3,
               "capacity": capacity, "num_requests": n_requests,
               "informed_win_rates": informed_wins,
               "cells": table}
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_serving.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.normpath(path)}")
    return rows


MICRO_BENCHES = {
    "bench_planner": lambda smoke: bench_planner(),
    "bench_failover": bench_failover,
    "bench_fabrics": bench_fabrics,
    "bench_calibration": bench_calibration,
    "bench_overlap": bench_overlap,
    "bench_program": bench_program,
    "bench_allreduce": bench_allreduce,
    "bench_contention": bench_contention,
    "bench_serving": bench_serving,
    "bench_kernels": lambda smoke: bench_kernels(),
    "bench_dispatch_sim": lambda smoke: bench_dispatch_sim(),
    "bench_train_throughput": lambda smoke: bench_train_throughput(),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="bench_fabrics: only the (plan x fabric) simulate "
                         "smoke (tiny payloads) — the CI gate")
    args = ap.parse_args(argv)

    from benchmarks import paper_figures
    known = set(paper_figures.ALL) | set(MICRO_BENCHES)
    if args.only is not None and args.only not in known:
        ap.error(f"--only {args.only!r}: unknown bench "
                 f"(have {', '.join(sorted(known))})")
    csv_rows = []
    for name, fn in paper_figures.ALL.items():
        if args.only and args.only != name:
            continue
        rows = fn()
        for r in rows:
            tag = r.get('scheme', r.get('batch', r.get('msg_mb', '')))
            for k, v in r.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    csv_rows.append((f"{name}.{tag}", k, v))
    for name, bench in MICRO_BENCHES.items():
        if args.only is None or args.only == name:
            for r in bench(args.smoke):
                csv_rows.append((r["name"], r["metric"], r["value"]))

    print("\nname,metric,value")
    for name, metric, value in csv_rows:
        print(f"{name},{metric},{value}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
