"""Roofline table builder: reads results/dryrun/*.json into §Roofline.

Per (arch x shape x mesh): the three terms (compute / memory /
collective), the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness
ratio, and a one-line lever suggestion.  Emits markdown for EXPERIMENTS.md
and CSV for machines.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

LEVERS = {
    "compute": "raise arithmetic intensity: larger per-chip batch or "
               "fewer remat recomputes",
    "memory": "cut HBM traffic: fuse ops, bf16 storage, larger attention "
              "blocks, microbatch the MoE dispatch",
    "collective": "cut bottleneck-axis bytes: MultiWrite dedup (pod), "
                  "overlap collectives with compute, int8-compress DP "
                  "gradients",
}


def load(variant="mw"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("variant") != variant:
            continue
        rows.append(r)
    return rows


_MODEL_FLOPS_CACHE: dict = {}


def model_flops(arch: str, shape: str) -> float:
    """Recompute 6*N*D (authoritative — older result JSONs may carry a
    stale prefill token count)."""
    key = (arch, shape)
    if key not in _MODEL_FLOPS_CACHE:
        from repro.configs.base import SHAPES
        from repro.launch.dryrun import model_flops_per_step
        _MODEL_FLOPS_CACHE[key] = model_flops_per_step(arch, SHAPES[shape])
    return _MODEL_FLOPS_CACHE[key]


def axis_parallel_collective(r) -> float:
    """Per-axis collective times overlap across axes: each mesh axis rides
    a different physical torus dimension (v5e 2D/3D ICI) — take the max
    axis instead of the sum.  (The stored collective_term_s is the
    conservative serial sum.)"""
    ax = r.get("collectives", {}).get("by_axis", {})
    times = [v / (6.25e9 if k == "pod" else 50e9) for k, v in ax.items()]
    return max(times) if times else 0.0


def fraction(r):
    """Roofline fraction: useful-model-time / max(terms) — how close the
    dominant resource runs to doing only useful work.  Collective uses
    the axis-parallel (max-axis) model; the serial-sum variant is also
    reported in the terms dict."""
    rl = r["roofline"]
    terms = {"compute": rl["compute_term_s"], "memory": rl["memory_term_s"],
             "collective": rl["collective_term_s"],
             "collective_axis_max": axis_parallel_collective(r)}
    bound = max(terms["compute"], terms["memory"],
                terms["collective_axis_max"])
    useful = model_flops(r["arch"], r["shape"]) / (r["chips"] * 197e12)
    return useful / bound if bound else 0.0, terms


def markdown(rows):
    out = ["| arch | shape | mesh | compute ms | memory ms | coll ms (sum) "
           "| coll ms (axis-max) | dominant | useful/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP: {r['skipped'][:40]}… | | | | | | |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | | | | | | |")
            continue
        frac, terms = fraction(r)
        flops_dev = r["cost"]["flops_per_device"]
        ratio = (model_flops(r["arch"], r["shape"])
                 / (flops_dev * r["chips"]) if flops_dev else 0.0)
        dom = max([("compute", terms["compute"]),
                   ("memory", terms["memory"]),
                   ("collective", terms["collective_axis_max"])],
                  key=lambda kv: kv[1])[0]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {terms['compute']*1e3:.2f} | {terms['memory']*1e3:.2f} "
            f"| {terms['collective']*1e3:.2f} "
            f"| {terms['collective_axis_max']*1e3:.2f} | {dom} "
            f"| {ratio:.2f} | {frac:.3f} |")
    return "\n".join(out)


def main():
    rows = load()
    print(markdown(rows))
    ok = [r for r in rows if "error" not in r and "skipped" not in r]
    print(f"\n{len(ok)} cells analyzed; dominant-term histogram:")
    from collections import Counter
    hist = Counter(r["roofline"]["dominant"] for r in ok)
    for k, v in hist.items():
        print(f"  {k}: {v}   lever: {LEVERS[k]}")


if __name__ == "__main__":
    main()
