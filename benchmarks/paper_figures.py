"""Paper-table/figure reproductions (one function per table/figure).

Each function returns a list of row-dicts and prints a compact table;
benchmarks.run drives them all and emits CSV.  Sources:

  fig2_section31   §3.1 derivation table (exact, zero-overhead regime)
  fig6_allgather   AllGather latency at 16 MB: baseline vs unicast
                   multipath vs MultiWrite (calibrated model + simulator
                   byte ledger)
  fig7_sweep       AllGather latency vs message size, crossover point
  fig8_dispatch    AlltoAll dispatch e2e latency vs batch (decode/prefill)
  table1_cross     cross-server transfer times w/ and w/o redundancy vs
                   the paper's measured numbers (+ % error)
  table_jax_bytes  pod-axis bytes of the JAX hierarchical vs baseline
                   dispatch (dry-run collective parse / analytic)
"""

from __future__ import annotations

import numpy as np

from repro.core import latency_model as lm
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import HCCS_LINK_BW, split_tp_full_mesh, \
    two_server_cluster


def _print(title, rows):
    print(f"\n== {title} ==")
    if not rows:
        return
    keys = list(rows[0])
    print("  " + " | ".join(f"{k:>18s}" for k in keys))
    for r in rows:
        print("  " + " | ".join(f"{_fmt(r[k]):>18s}" for k in keys))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def fig2_section31():
    """§3.1 exact derivations (ideal regime)."""
    s, w = 16 * 2**20, HCCS_LINK_BW
    rows = []
    base = lm.allgather_latency("baseline", s, w, lm.IDEAL)
    for scheme in lm.ALLGATHER_LINK_LOAD:
        t = lm.allgather_latency(scheme, s, w, lm.IDEAL)
        rows.append({"scheme": scheme, "latency_us": t * 1e6,
                     "vs_baseline_pct": 100 * (1 - t / base)})
    _print("§3.1 derivations (ideal)", rows)
    return rows


def fig6_allgather():
    s = lm.FIG6_MESSAGE_BYTES
    rows = []
    base = lm.allgather_latency("baseline", s)
    paper = {"baseline": 0.0, "unicast_paired": None,
             "multiwrite_paired": 30.0}
    for scheme in ("baseline", "unicast_paired", "multiwrite_paired"):
        t = lm.allgather_latency(scheme, s)
        rows.append({
            "scheme": scheme, "latency_us": t * 1e6,
            "reduction_pct": 100 * (1 - t / base),
            "paper_pct": paper[scheme] if paper[scheme] is not None else "-",
        })
    # simulator ledger cross-check (bytes -> same model)
    topo, domains = split_tp_full_mesh(8, tp=4)
    for scheme in ("baseline", "multiwrite_paired"):
        sim = MultiWriteSimulator(topo)
        pay = [np.zeros(1 << 16, np.uint8) for _ in range(8)]
        sch.ALLGATHER_SCHEMES[scheme](sim, domains, pay)
        t = lm.ledger_latency(sim)
        rows.append({"scheme": f"{scheme} (ledger 64KB)",
                     "latency_us": t * 1e6, "reduction_pct": "-",
                     "paper_pct": "-"})
    _print("Fig 6: AllGather @ 16MB", rows)
    return rows


def fig7_sweep():
    rows = []
    for s in lm.FIG7_MESSAGE_BYTES:
        tb = lm.allgather_latency("baseline", s)
        tm = lm.allgather_latency("multiwrite_paired", s)
        rows.append({"msg_mb": s / 2**20, "baseline_us": tb * 1e6,
                     "multiwrite_us": tm * 1e6,
                     "mw_better": bool(tm < tb)})
    x = lm.allgather_crossover_bytes()
    rows.append({"msg_mb": f"crossover={x/2**20:.2f}MB (paper ~2MB)",
                 "baseline_us": "-", "multiwrite_us": "-", "mw_better": "-"})
    _print("Fig 7: message-size sweep", rows)
    return rows


def fig8_dispatch():
    rows = []
    for b in lm.FIG8_BATCHES:
        tu = lm.dispatch_e2e_time(b, "unicast")
        tm = lm.dispatch_e2e_time(b, "multiwrite")
        paper = {64: "mw worse", 128: "~parity", 1024: "-12%",
                 2048: "-27%"}[b]
        rows.append({"batch": b, "unicast_us": tu * 1e6,
                     "multiwrite_us": tm * 1e6,
                     "reduction_pct": 100 * (1 - tm / tu),
                     "paper": paper})
    _print("Fig 8: AlltoAll dispatch e2e", rows)
    return rows


def table1_cross():
    rows = []
    for b, (p_w, p_wo) in sorted(lm.TABLE1_PAPER_US.items()):
        m_w = lm.dispatch_cross_server_time(b, True) * 1e6
        m_wo = lm.dispatch_cross_server_time(b, False) * 1e6
        rows.append({
            "batch": b,
            "w_red_model_us": m_w, "w_red_paper_us": p_w,
            "w_err_pct": 100 * (m_w - p_w) / p_w,
            "wo_red_model_us": m_wo, "wo_red_paper_us": p_wo,
            "wo_err_pct": 100 * (m_wo - p_wo) / p_wo,
        })
    _print("Table 1: cross-server transfer", rows)
    return rows


def table1_ledger():
    """Table 1 regenerated from the packet-level simulator (actual random
    routing, not expectations)."""
    rows = []
    for b in (64, 128, 1024):
        topo = two_server_cluster()
        sim_u = MultiWriteSimulator(topo)
        sim_m = MultiWriteSimulator(topo)
        routing = sch.make_routing(b, 16, 64, 8, seed=b)
        sch.dispatch_unicast(sim_u, routing, lm.TOKEN_BYTES)
        sch.dispatch_multiwrite(sim_m, routing, lm.TOKEN_BYTES)

        def rail_time(sim):
            rail = max((v for (a, bb), v in sim.link_bytes.items()
                        if a // 8 != bb // 8), default=0)
            return rail / 25e9

        rows.append({"batch": b,
                     "unicast_rail_us": rail_time(sim_u) * 1e6,
                     "mw_rail_us": rail_time(sim_m) * 1e6,
                     "ratio": rail_time(sim_u) / max(rail_time(sim_m), 1e-12)})
    _print("Table 1 (simulator ledger, rail serialization only)", rows)
    return rows


ALL = {
    "fig2_section31": fig2_section31,
    "fig6_allgather": fig6_allgather,
    "fig7_sweep": fig7_sweep,
    "fig8_dispatch": fig8_dispatch,
    "table1_cross": table1_cross,
    "table1_ledger": table1_ledger,
}
